//! SCALE-Sim-equivalent systolic-array simulator — the digital TPU side
//! of the hybrid architecture (paper §III-A, Fig. 3a, Fig. 4).
//!
//! Two levels of fidelity:
//!
//! * [`dataflow`] — closed-form analytical cycle models for the three
//!   classic dataflows (output-, weight-, input-stationary), the level
//!   SCALE-Sim's analytical mode and the paper's Fig. 4 operate at.
//! * [`wavefront`] — a cycle-accurate stepper that actually marches the
//!   skewed wavefront through an R x C PE grid and counts cycles; used by
//!   property tests to validate the analytical formulas on small shapes.
//!
//! Plus SRAM traffic/utilization accounting used by the energy model.

pub mod dataflow;
pub mod trace;
pub mod wavefront;

pub use dataflow::{gemm_cycles, Dataflow};

use crate::config::TpuConfig;
use crate::workload::MatMulOp;

/// Result of running one GEMM/MVM on the array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystolicRun {
    pub cycles: u64,
    pub macs: u64,
    /// Fraction of PE-cycles doing useful MACs.
    pub utilization: f64,
    /// Bytes read from the input+weight SRAMs.
    pub sram_read_bytes: u64,
    /// Bytes written to the output SRAM.
    pub sram_write_bytes: u64,
}

/// Simulate one op on the array with the given dataflow.
pub fn run_op(tpu: &TpuConfig, op: &MatMulOp, dataflow: Dataflow) -> SystolicRun {
    run_gemm(tpu, op.m, op.k, op.n, dataflow)
}

/// Simulate an (M x K).(K x N) GEMM on the R x C array.
pub fn run_gemm(
    tpu: &TpuConfig,
    m: usize,
    k: usize,
    n: usize,
    dataflow: Dataflow,
) -> SystolicRun {
    let cycles = gemm_cycles(m, k, n, tpu.rows, tpu.cols, dataflow);
    let macs = m as u64 * k as u64 * n as u64;
    let pe_cycles = cycles * (tpu.rows as u64) * (tpu.cols as u64);
    // SRAM traffic: operands are read once per fold they participate in;
    // int8 operands, int32 partial sums written once per output.
    let (reads, writes) = sram_traffic(m, k, n, tpu.rows, tpu.cols, dataflow);
    SystolicRun {
        cycles,
        macs,
        utilization: macs as f64 / pe_cycles.max(1) as f64,
        sram_read_bytes: reads,
        sram_write_bytes: writes,
    }
}

/// SRAM bytes (reads, writes) for a GEMM under a dataflow. int8 operands;
/// each fold re-reads the operands it streams; outputs written once
/// (int8 after requantization, matching the W8A8 pipeline).
pub fn sram_traffic(
    m: usize,
    k: usize,
    n: usize,
    r: usize,
    c: usize,
    dataflow: Dataflow,
) -> (u64, u64) {
    let (m64, k64, n64) = (m as u64, k as u64, n as u64);
    let folds_m = m.div_ceil(r) as u64;
    let folds_n = n.div_ceil(c) as u64;
    let reads = match dataflow {
        // OS: for each (m-fold, n-fold) output tile, stream A rows and B
        // columns of depth K.
        Dataflow::OutputStationary => folds_n * (m64 * k64) + folds_m * (k64 * n64),
        // WS: weights loaded once (K*N), inputs re-read once per n-fold.
        Dataflow::WeightStationary => k64 * n64 + folds_n * (m64 * k64),
        // IS: inputs loaded once (M*K), weights re-read per m-fold.
        Dataflow::InputStationary => m64 * k64 + folds_m * (k64 * n64),
    };
    let writes = m64 * n64;
    (reads, writes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TpuConfig;

    fn tpu() -> TpuConfig {
        TpuConfig::default()
    }

    #[test]
    fn utilization_bounded() {
        for (m, k, n) in [(1, 64, 64), (128, 128, 1), (4096, 4096, 1)] {
            for df in [
                Dataflow::OutputStationary,
                Dataflow::WeightStationary,
                Dataflow::InputStationary,
            ] {
                let r = run_gemm(&tpu(), m, k, n, df);
                assert!(r.utilization > 0.0 && r.utilization <= 1.0);
            }
        }
    }

    #[test]
    fn mvm_cycles_match_hand_formula_os() {
        // OS: ceil(M/R)*ceil(N/C)*(K + R + C - 2); 32x32 array.
        let r = run_gemm(&tpu(), 4096, 4096, 1, Dataflow::OutputStationary);
        assert_eq!(r.cycles, 128 * (4096 + 62));
    }

    #[test]
    fn writes_are_output_sized() {
        let r = run_gemm(&tpu(), 100, 200, 3, Dataflow::OutputStationary);
        assert_eq!(r.sram_write_bytes, 300);
    }
}
