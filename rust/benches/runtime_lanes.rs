//! Bench for the lane scheduler: chunked prefill (`--prefill-chunk`)
//! and greedy-exact speculative decoding (`--spec-draft`).
//!
//! Two claims, two sections:
//!
//! **Mixed stream** — long prompts arriving next to short ones. Classic
//! pacing ingests every prompt one position per tick (one full weight
//! traversal per position); the chunked lane feeds `chunk` positions
//! through ONE `decode_span` traversal, so long-prompt ingestion gets
//! cheaper without starving short requests: p95 TTFT should stay flat
//! (or drop) while tokens/s rises.
//!
//! **Decode stream** — the latency-bound single-lane regime where
//! speculative decoding earns its keep. The oracle draft replays a
//! recorded reference run (100% acceptance by construction), so the
//! measured speedup is the HARNESS BOUND: k accepted positions per
//! weight traversal instead of one. On a weight-traversal-dominated
//! model that must clear >= 1.5x tokens/s at k = 4 — real drafts land
//! between this bound and 1x depending on acceptance.
//!
//! Both sections assert the served tokens match the classic run
//! bit-for-bit — the lanes are scheduling only.
//!
//! Emits `BENCH_lanes.json` at the repo root.
//!
//! Run: `cargo bench --bench runtime_lanes`

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, Engine, SpecPlan};
use pim_llm::serving::{LaneStats, LatencyStats, Policy, Request, Server};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;
use std::collections::HashMap;
use std::time::Instant;

const BLOCK_LEN: usize = 4;
const ARENA_BLOCKS: usize = 96;
const MAX_ACTIVE: usize = 4;
const PREFILL_CHUNK: usize = 8;
const SPEC_K: usize = 4;
const N_MIXED: usize = 12;
const N_DECODE: usize = 6;

/// The weight-traversal-dominated regime (same sizing rationale as
/// `runtime_kvq`'s "sized" model): d large enough that streaming the
/// weights dwarfs per-position work, so span amortization shows.
fn sized_artifacts() -> Result<Artifacts> {
    Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 2048,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )
}

/// Alternating long-prompt ingestion jobs and short interactive
/// requests — the head-of-line shape chunked prefill is for.
fn mixed_requests(vocab: usize) -> Vec<Request> {
    (0..N_MIXED as u64)
        .map(|id| {
            let i = id as usize;
            let (prompt_len, n_new) = if i % 2 == 0 { (24, 2) } else { (2, 6) };
            Request {
                id,
                prompt: (0..prompt_len)
                    .map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32)
                    .collect(),
                n_new,
            }
        })
        .collect()
}

/// Generation-heavy single-lane stream for the decode section.
fn decode_requests(vocab: usize) -> Vec<Request> {
    (0..N_DECODE as u64)
        .map(|id| {
            let i = id as usize;
            Request {
                id,
                prompt: (0..2).map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32).collect(),
                n_new: 24,
            }
        })
        .collect()
}

fn total_tokens(reqs: &[Request]) -> usize {
    reqs.iter().map(|r| r.prompt.len() + r.n_new).sum()
}

fn assert_same_tokens(base: &[(u64, Vec<i32>)], out: &[(u64, Vec<i32>)], label: &str) {
    assert_eq!(base, out, "{label}: lane scheduling changed served tokens");
}

fn sorted_tokens(out: &[pim_llm::serving::Response]) -> Vec<(u64, Vec<i32>)> {
    let mut v: Vec<_> = out.iter().map(|r| (r.id, r.tokens.clone())).collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();
    let artifacts = sized_artifacts()?;
    let vocab = artifacts.manifest.model.vocab;
    let engine =
        Engine::load_with_arena(artifacts.clone(), BackendKind::Reference, BLOCK_LEN, ARENA_BLOCKS)?;

    // ---- mixed stream: chunked prefill on/off ------------------------
    let mixed = mixed_requests(vocab);
    let mixed_total = total_tokens(&mixed);
    // Stagger arrivals at twice the single-stream token cadence so the
    // scheduler sees genuine interleaving, not a pre-filled queue.
    let t0 = Instant::now();
    let warm = Server::new(&engine, Policy::Fifo).serve(vec![mixed[0].clone()])?;
    let per_token = t0.elapsed().as_secs_f64()
        / (mixed[0].prompt.len() + mixed[0].n_new) as f64;
    let offs: Vec<f64> = (0..mixed.len()).map(|i| i as f64 * per_token * 2.0).collect();
    drop(warm);

    let section = |bench: &mut Bench,
                   label: &str,
                   chunk: usize|
     -> Result<(f64, f64, Vec<(u64, Vec<i32>)>)> {
        let serve = || -> Result<(f64, LatencyStats, Vec<(u64, Vec<i32>)>)> {
            let t0 = Instant::now();
            let out = Server::new(&engine, Policy::Continuous { max_active: MAX_ACTIVE })
                .with_prefill_chunk(chunk)
                .serve_arrivals(mixed.clone(), &offs)?;
            let wall = t0.elapsed().as_secs_f64();
            let stats = LatencyStats::from_responses(&out, wall);
            Ok((wall, stats, sorted_tokens(&out)))
        };
        let (_, stats, tokens) = serve()?;
        let m = bench.run(&format!("mixed/{label}"), || black_box(serve().unwrap()));
        let tps = mixed_total as f64 / m.mean_s;
        println!(
            "  mixed/{label}: {tps:9.1} tok/s | p95 ttft {:7.4}s | p95 service {:7.4}s",
            stats.p95_ttft_s, stats.p95_service_s
        );
        Ok((tps, stats.p95_ttft_s, tokens))
    };
    println!("== mixed stream: {N_MIXED} requests, {mixed_total} tokens ==");
    let (tps_unchunked, ttft_unchunked, base_tokens) = section(&mut bench, "unchunked", 0)?;
    let (tps_chunked, ttft_chunked, chunk_tokens) =
        section(&mut bench, "chunked", PREFILL_CHUNK)?;
    assert_same_tokens(&base_tokens, &chunk_tokens, "mixed/chunked");

    // ---- decode stream: spec off vs oracle draft ---------------------
    let decode = decode_requests(vocab);
    let decode_total = total_tokens(&decode);
    println!("\n== decode stream: {N_DECODE} requests, {decode_total} tokens, k={SPEC_K} ==");
    let base_out = Server::new(&engine, Policy::Fifo).serve(decode.clone())?;
    let base_decode_tokens = sorted_tokens(&base_out);
    // The oracle book IS the reference run: same engine, same kv layout,
    // same block geometry — the 100%-acceptance throughput bound.
    let book: HashMap<u64, Vec<i32>> =
        base_out.into_iter().map(|r| (r.id, r.tokens)).collect();
    let plan = SpecPlan::oracle(book, SPEC_K)?;

    let m_off = bench.run("decode/spec_off", || {
        black_box(Server::new(&engine, Policy::Fifo).serve(decode.clone()).unwrap())
    });
    let tps_off = decode_total as f64 / m_off.mean_s;

    engine.obs().set_enabled(true);
    let spec_out = Server::new(&engine, Policy::Fifo)
        .with_spec(&plan)?
        .serve(decode.clone())?;
    let lanes = LaneStats::from_obs(engine.obs());
    engine.obs().set_enabled(false);
    assert_same_tokens(&base_decode_tokens, &sorted_tokens(&spec_out), "decode/oracle");

    let m_spec = bench.run("decode/spec_oracle", || {
        black_box(
            Server::new(&engine, Policy::Fifo)
                .with_spec(&plan)
                .unwrap()
                .serve(decode.clone())
                .unwrap(),
        )
    });
    let tps_spec = decode_total as f64 / m_spec.mean_s;
    let speedup = tps_spec / tps_off.max(f64::MIN_POSITIVE);
    let acceptance = lanes.acceptance();
    println!(
        "  decode: {tps_off:9.1} tok/s off | {tps_spec:9.1} tok/s oracle | \
         {speedup:.2}x | acceptance {:.1}% ({}/{} proposals)",
        acceptance * 100.0,
        lanes.accepted,
        lanes.proposed,
    );
    assert!(
        acceptance > 0.99,
        "oracle draft must accept every proposal, got {:.3}",
        acceptance
    );
    assert!(
        speedup >= 1.5,
        "oracle-draft decode must clear 1.5x tokens/s at k={SPEC_K} \
         (got {speedup:.2}x): span verification is not amortizing the \
         weight traversal"
    );

    let json = format!(
        "{{\n  \"bench\": \"runtime_lanes\",\n  \"block_len\": {BLOCK_LEN},\n  \
         \"arena_blocks\": {ARENA_BLOCKS},\n  \"max_active\": {MAX_ACTIVE},\n  \
         \"requests\": {N_MIXED},\n  \"prefill_chunk\": {PREFILL_CHUNK},\n  \
         \"spec_k\": {SPEC_K},\n  \"mixed\": {{\n    \
         \"tokens_per_s_unchunked\": {tps_unchunked:.1},\n    \
         \"tokens_per_s_chunked\": {tps_chunked:.1},\n    \
         \"ttft_p95_unchunked_s\": {ttft_unchunked:.5},\n    \
         \"ttft_p95_chunked_s\": {ttft_chunked:.5}\n  }},\n  \"decode\": {{\n    \
         \"tokens_per_s_off\": {tps_off:.1},\n    \
         \"tokens_per_s_oracle\": {tps_spec:.1},\n    \
         \"speedup_oracle\": {speedup:.3},\n    \
         \"acceptance\": {acceptance:.4}\n  }}\n}}\n"
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_lanes.json");
    std::fs::write(path, &json)
        .map_err(|e| pim_llm::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}
