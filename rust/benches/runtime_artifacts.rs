//! Bench for `.tpk` packed-artifact loading: engine-start cost of
//! `load_tpk` (header validation + mmap, O(1) in the weights) vs
//! `PackedModel::lower` (the per-matrix re-pack it replaces, O(weights))
//! on the tiny synthetic model and on a sized d=512 model.
//!
//! What is being isolated: model-load latency only — no decode. The
//! loaded planes are first asserted bit-identical to the lowered ones
//! (the bench refuses to time a wrong answer), then both paths are
//! timed on the same artifacts. The `.tpk` file lives in the OS temp
//! dir and is written once outside the timed region; repeated loads hit
//! the page cache, which is exactly the deployment story (N serving
//! processes mmap one warm file).
//!
//! Headline: load/lower speedup on the sized model — the bigger the
//! model, the bigger the win, because load cost stays header-sized.
//!
//! Emits `BENCH_artifacts.json` at the repo root.
//!
//! Run: `cargo bench --bench runtime_artifacts`

use pim_llm::quant::{load_tpk, write_tpk, PackedModel};
use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::Artifacts;
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;

struct Point {
    label: &'static str,
    lower_s: f64,
    load_s: f64,
    speedup: f64,
    file_bytes: u64,
    packed_bytes: usize,
}

fn bench_model(bench: &mut Bench, label: &'static str, artifacts: &Artifacts) -> Result<Point> {
    let lowered = PackedModel::lower(artifacts)?;
    let path = std::env::temp_dir().join(format!(
        "pimllm-bench-artifacts-{label}-{}.tpk",
        std::process::id()
    ));
    write_tpk(&path, &lowered, &artifacts.manifest)?;

    // Correctness gate before any timing: every plane of the loaded
    // model must be bit-identical to the lowered one.
    let loaded = load_tpk(&path, artifacts)?;
    assert_eq!(loaded.matrices().len(), lowered.matrices().len());
    for ((name, lm), (_, rm)) in lowered.matrices().iter().zip(loaded.matrices().iter()) {
        assert_eq!(lm, rm, "'{name}': .tpk round trip must be bit-identical");
    }
    drop(loaded);

    let ml = bench.run(&format!("{label}/lower"), || {
        black_box(PackedModel::lower(artifacts).unwrap())
    });
    let mo = bench.run(&format!("{label}/load_tpk"), || {
        black_box(load_tpk(&path, artifacts).unwrap())
    });
    let file_bytes = std::fs::metadata(&path)
        .map_err(|e| pim_llm::anyhow!("stat {}: {e}", path.display()))?
        .len();
    std::fs::remove_file(&path).ok();

    let speedup = ml.mean_s / mo.mean_s.max(f64::MIN_POSITIVE);
    println!(
        "  {label}: lower {:9.1} us | load_tpk {:9.1} us | {speedup:6.1}x faster start \
         | file {file_bytes} bytes",
        1e6 * ml.mean_s,
        1e6 * mo.mean_s,
    );
    Ok(Point {
        label,
        lower_s: ml.mean_s,
        load_s: mo.mean_s,
        speedup,
        file_bytes,
        packed_bytes: lowered.packed_bytes(),
    })
}

fn json_point(p: &Point) -> String {
    format!(
        "    {{\"model\": \"{}\", \"lower_s\": {:.6e}, \"load_tpk_s\": {:.6e}, \
         \"speedup\": {:.2}, \"file_bytes\": {}, \"packed_bytes\": {}}}",
        p.label, p.lower_s, p.load_s, p.speedup, p.file_bytes, p.packed_bytes
    )
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== tiny model (d=32) ==");
    let tiny = Artifacts::synthetic(0)?;
    let tiny_point = bench_model(&mut bench, "tiny", &tiny)?;

    println!("\n== sized model (d=512, d_ff=1536) ==");
    let sized = Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 1536,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?;
    let sized_point = bench_model(&mut bench, "sized", &sized)?;

    println!(
        "\npacked-artifact start: load_tpk is {:.1}x faster than re-packing on the \
         sized model (bit-identical planes; the gap grows with model size — load \
         cost is header-sized, re-pack cost is weight-sized)",
        sized_point.speedup
    );

    let json = format!(
        "{{\n  \"bench\": \"runtime_artifacts\",\n  \"models\": [\n{},\n{}\n  ]\n}}\n",
        json_point(&tiny_point),
        json_point(&sized_point)
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_artifacts.json");
    std::fs::write(path, &json).map_err(|e| pim_llm::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}
