//! Bench for the sharded multi-worker serving engine: tokens/s and p95
//! TTFT vs `workers ∈ {1, 2, 4, 8}` at EQUAL TOTAL arena capacity, on a
//! staggered-arrival, mixed-length request stream.
//!
//! What is being isolated: worker-thread parallelism of the serving
//! engine itself, NOT intra-kernel parallelism. The sized model is
//! deliberately shaped (d=512, d_ff=1536) so the largest per-call
//! matmul at the per-worker batch width (2 lanes) stays UNDER the
//! kernels' `PAR_MAC_THRESHOLD` (2 * 512 * 1536 = 1,572,864 MACs <
//! 2^21) — each worker therefore decodes single-threaded and the 1-vs-N
//! curve measures shard parallelism alone, without nested-parallelism
//! oversubscription muddying either end. Per-worker lanes are held
//! constant (2), so N workers also mean N times the decode lanes — the
//! deployment question "what does another worker buy me at the same
//! total arena?".
//!
//! Every configuration must produce byte-identical tokens (asserted
//! against a FIFO oracle; `tests/shard_determinism.rs` is the
//! exhaustive version). Headline: 4-worker tokens/s vs 1-worker on the
//! sized model (target >= 2.5x on a >= 4-core host).
//!
//! Emits `BENCH_sharded.json` at the repo root with the per-worker-count
//! numbers for both models.
//!
//! Run: `cargo bench --bench runtime_sharded`

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, Engine, ShardedEngine};
use pim_llm::serving::{serve_sharded_arrivals, LatencyStats, Policy, Request, Server};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;
use std::time::Instant;

const WORKER_COUNTS: [usize; 4] = [1, 2, 4, 8];
const LANES_PER_WORKER: usize = 2;
const N_REQUESTS: usize = 24;
const BLOCK_LEN: usize = 4;
const TOTAL_BLOCKS: usize = 48;

/// Mixed-length, generation-heavy stream: short prompts, alternating
/// short and long generation budgets, dense ids so the placement hash
/// spreads work across up to 8 shards.
fn requests(vocab: usize) -> Vec<Request> {
    (0..N_REQUESTS as u64)
        .map(|id| {
            let i = id as usize;
            Request {
                id,
                prompt: (0..1 + i % 4)
                    .map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32)
                    .collect(),
                n_new: if i % 2 == 0 { 4 } else { 10 + (i % 4) * 2 },
            }
        })
        .collect()
}

struct Point {
    workers: usize,
    tokens_per_s: f64,
    p95_ttft_s: f64,
}

/// Serve the stream once on a fresh sharded engine; returns
/// (tokens/s, p95 TTFT), asserting tokens against the oracle when
/// given.
fn serve_once(
    artifacts: &Artifacts,
    workers: usize,
    reqs: &[Request],
    offs: &[f64],
    oracle: Option<&[(u64, Vec<i32>)]>,
) -> Result<(f64, f64)> {
    let mut engine = ShardedEngine::load(
        artifacts.clone(),
        BackendKind::Reference,
        BLOCK_LEN,
        TOTAL_BLOCKS,
        workers,
    )?;
    let t0 = Instant::now();
    let out = serve_sharded_arrivals(&mut engine, reqs.to_vec(), offs, LANES_PER_WORKER)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = LatencyStats::from_responses(&out, wall);
    if let Some(want) = oracle {
        for (id, tokens) in want {
            let got = out.iter().find(|r| r.id == *id).expect("response");
            assert_eq!(&got.tokens, tokens, "request {id}: worker counts must agree");
        }
    }
    Ok((stats.tokens_per_s, stats.p95_ttft_s))
}

/// Bench one model across the worker counts at equal total capacity.
fn bench_model(bench: &mut Bench, label: &str, artifacts: &Artifacts) -> Result<Vec<Point>> {
    let reqs = requests(artifacts.manifest.model.vocab);
    let total_tokens: usize = reqs.iter().map(|r| r.prompt.len() + r.n_new).sum();
    println!(
        "  {label}: {} requests, {} tokens, arena {TOTAL_BLOCKS} blocks x {BLOCK_LEN} \
         positions total, {LANES_PER_WORKER} lanes/worker",
        reqs.len(),
        total_tokens,
    );

    // Calibrate the arrival stagger to ~1 token of measured decode time
    // so the arrival shape survives machine-speed differences.
    let single = Engine::load_with_arena(
        artifacts.clone(),
        BackendKind::Reference,
        BLOCK_LEN,
        TOTAL_BLOCKS,
    )?;
    let t0 = Instant::now();
    Server::new(&single, Policy::Fifo).serve(vec![reqs[0].clone()])?;
    let per_token =
        t0.elapsed().as_secs_f64() / (reqs[0].prompt.len() + reqs[0].n_new) as f64;
    let offs: Vec<f64> = (0..reqs.len()).map(|i| i as f64 * per_token).collect();

    // Token oracle from the single-engine FIFO server.
    let oracle: Vec<(u64, Vec<i32>)> = Server::new(&single, Policy::Fifo)
        .serve(reqs.clone())?
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    drop(single);

    let mut points = Vec::new();
    for workers in WORKER_COUNTS {
        // Untimed instrumented run: token contract + p95 TTFT.
        let (_, p95_ttft) = serve_once(artifacts, workers, &reqs, &offs, Some(&oracle))?;
        // Timed runs (engine construction inside: a deployment brings
        // up its shards once per process, but rebuilding per run keeps
        // every iteration identical; construction is microseconds next
        // to the serve).
        let m = bench.run(&format!("{label}/sharded_w{workers}"), || {
            black_box(serve_once(artifacts, workers, &reqs, &offs, None).unwrap())
        });
        let tps = total_tokens as f64 / m.mean_s;
        println!(
            "  {label}: {workers} worker(s) {tps:9.1} tok/s | p95 TTFT {p95_ttft:7.3}s"
        );
        points.push(Point {
            workers,
            tokens_per_s: tps,
            p95_ttft_s: p95_ttft,
        });
    }
    Ok(points)
}

fn json_points(points: &[Point]) -> String {
    points
        .iter()
        .map(|p| {
            format!(
                "    {{\"workers\": {}, \"tokens_per_s\": {:.1}, \"p95_ttft_s\": {:.4}}}",
                p.workers, p.tokens_per_s, p.p95_ttft_s
            )
        })
        .collect::<Vec<_>>()
        .join(",\n")
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== tiny model (d=32, overhead-dominated) ==");
    let tiny = Artifacts::synthetic(0)?;
    let tiny_points = bench_model(&mut bench, "tiny", &tiny)?;

    println!("\n== sized model (d=512, d_ff=1536: weight traversal under PAR_MAC_THRESHOLD) ==");
    let sized = Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 1536,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?;
    let sized_points = bench_model(&mut bench, "sized", &sized)?;

    let tps_at = |pts: &[Point], w: usize| {
        pts.iter()
            .find(|p| p.workers == w)
            .map(|p| p.tokens_per_s)
            .unwrap_or(f64::NAN)
    };
    let speedup = tps_at(&sized_points, 4) / tps_at(&sized_points, 1).max(f64::MIN_POSITIVE);
    println!(
        "\nsharded serving, staggered mixed-length stream, equal total arena: \
         4 workers = {speedup:.2}x 1-worker tokens/s on the sized model \
         (identical tokens; target >= 2.5x on a >= 4-core host; \
         {} cores available here)",
        std::thread::available_parallelism().map_or(1, |n| n.get())
    );

    let json = format!(
        "{{\n  \"bench\": \"runtime_sharded\",\n  \"block_len\": {BLOCK_LEN},\n  \
         \"total_blocks\": {TOTAL_BLOCKS},\n  \"lanes_per_worker\": {LANES_PER_WORKER},\n  \
         \"requests\": {N_REQUESTS},\n  \"cores\": {},\n  \
         \"speedup_4w_over_1w_sized\": {speedup:.3},\n  \"tiny\": [\n{}\n  ],\n  \
         \"sized\": [\n{}\n  ]\n}}\n",
        std::thread::available_parallelism().map_or(1, |n| n.get()),
        json_points(&tiny_points),
        json_points(&sized_points)
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sharded.json");
    std::fs::write(path, &json)
        .map_err(|e| pim_llm::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}
