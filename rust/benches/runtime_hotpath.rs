//! Bench for the L3 runtime hot path: decode-step execution, cache
//! construction, and the serving loop — the targets of the perf pass
//! (EXPERIMENTS.md §Perf).
//!
//! Runs offline on the synthetic tiny model / reference backend; with
//! `make artifacts` the real AOT decoder is benched instead (and with
//! `--features pjrt` + `PIM_LLM_BACKEND=pjrt`, the PJRT engine).
//!
//! Run: `cargo bench --bench runtime_hotpath`

use pim_llm::runtime::{artifacts, Artifacts, Engine, TinyDecoder};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;

fn main() -> Result<()> {
    let dir = artifacts::default_dir();
    let have_real = dir.join("manifest.json").exists();

    let mut b = Bench::quick();

    // Artifact acquisition (cold-start cost).
    if have_real {
        b.run("runtime/artifacts_load", || {
            black_box(Artifacts::load(&dir).unwrap())
        });
    } else {
        b.run("runtime/artifacts_synthesize", || {
            black_box(Artifacts::synthetic(0).unwrap())
        });
    }
    let engine = Engine::load_default()?;
    println!(
        "engine: backend={} platform={}",
        engine.backend_name(),
        engine.platform()
    );

    // Single decode step (the per-token cost on the request path):
    // repeatedly decoding position 0 of one arena-backed session, so
    // the measured cost is the step itself, not session setup.
    let session = engine.new_session()?;
    b.run("runtime/decode_step", || {
        black_box(engine.decode_step(session, 1, 0).unwrap().len())
    });
    engine.free_session(session)?;

    // Session open/close against the paged arena (per-request setup —
    // replaces the old full-tensor `empty_caches` allocation).
    b.run("runtime/session_alloc_free", || {
        let s = engine.new_session().unwrap();
        engine.free_session(s).unwrap();
        black_box(s)
    });

    // Full short generation (prompt 4 + 8 new).
    b.run("runtime/generate_4p_8n", || {
        let mut dec = TinyDecoder::new(&engine).unwrap();
        dec.generate(&[1, 2, 3, 4], 8).unwrap();
        black_box(dec.tokens.len())
    });

    // Serving loop, round-robin over 4 sessions.
    b.run("serving/rr4_8req_4p_4n", || {
        let reqs: Vec<Request> = (0..8)
            .map(|id| Request {
                id,
                prompt: vec![1, 2, 3, 4],
                n_new: 4,
            })
            .collect();
        let out = Server::new(&engine, Policy::RoundRobin { max_active: 4 })
            .serve(reqs)
            .unwrap();
        black_box(out.len())
    });

    // Derived: report tokens/s of the functional path.
    let m = b
        .results()
        .iter()
        .find(|m| m.name == "runtime/decode_step")
        .unwrap()
        .clone();
    println!(
        "\nfunctional decode throughput: {:.1} tokens/s per engine",
        1.0 / m.mean_s
    );
    Ok(())
}
