//! Bench for the batched decode path: tokens/s vs batch size.
//!
//! The paper's throughput claim rests on amortizing weight access —
//! PIM banks are weight-stationary, so serving B users should cost ONE
//! weight traversal per step, not B. This bench measures exactly that
//! amortization in the reference backend: the same ragged greedy
//! workload served at batch sizes 1/2/4/8 through `BatchDecoder`
//! (one `decode_batch` per step), plus the sequential `TinyDecoder`
//! baseline (one `decode_step` per session per token).
//!
//! Two synthetic models are measured:
//! * the tiny test model (d=32) — overhead-dominated, small win;
//! * a sized-up model (d=512, weights ~27 MB, far beyond L2) — the
//!   weight-streaming regime the paper's argument is about, where the
//!   batched path's single traversal per step pays off. The headline
//!   line reports batch-8 vs batch-1 tokens/s on this model (target:
//!   >= 2x).
//!
//! Run: `cargo bench --bench runtime_batching`

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BatchDecoder, Engine, TinyDecoder};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;

const BATCH_SIZES: [usize; 4] = [1, 2, 4, 8];
const PROMPT_LEN: usize = 2;
const NEW_TOKENS: usize = 6;

/// Ragged-ish deterministic prompts for `b` sessions.
fn prompts(b: usize, vocab: usize) -> Vec<Vec<i32>> {
    (0..b)
        .map(|i| {
            (0..PROMPT_LEN)
                .map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32)
                .collect()
        })
        .collect()
}

/// tokens/s of the batched loop at batch size `b`.
fn bench_batched(bench: &mut Bench, label: &str, engine: &Engine, b: usize) -> f64 {
    let ps = prompts(b, engine.vocab());
    let n_new = vec![NEW_TOKENS; b];
    let tokens = b * (PROMPT_LEN + NEW_TOKENS);
    let m = bench.run(&format!("{label}/decode_batch_b{b}"), || {
        let mut dec = BatchDecoder::new(engine);
        let t = dec.generate(&ps, &n_new).unwrap();
        black_box(t.steps)
    });
    tokens as f64 / m.mean_s
}

/// tokens/s of the sequential baseline: the same `b`-session workload,
/// one `TinyDecoder` after another (one weight traversal per session
/// per step).
fn bench_sequential(bench: &mut Bench, label: &str, engine: &Engine, b: usize) -> f64 {
    let ps = prompts(b, engine.vocab());
    let tokens = b * (PROMPT_LEN + NEW_TOKENS);
    let m = bench.run(&format!("{label}/sequential_x{b}"), || {
        let mut produced = 0usize;
        for p in &ps {
            let mut dec = TinyDecoder::new(engine).unwrap();
            dec.generate(p, NEW_TOKENS).unwrap();
            produced += dec.tokens.len();
        }
        black_box(produced)
    });
    tokens as f64 / m.mean_s
}

fn bench_model(bench: &mut Bench, label: &str, engine: &Engine) -> (f64, f64) {
    let mut at_1 = 0.0;
    let mut at_8 = 0.0;
    for &b in &BATCH_SIZES {
        let tps = bench_batched(bench, label, engine, b);
        println!("  {label}: batch {b:>2} -> {tps:9.1} tok/s");
        if b == 1 {
            at_1 = tps;
        }
        if b == 8 {
            at_8 = tps;
        }
    }
    let seq = bench_sequential(bench, label, engine, 8);
    println!("  {label}: sequential 8 sessions -> {seq:9.1} tok/s");
    (at_1, at_8)
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== tiny model (d=32, overhead-dominated) ==");
    let tiny = Engine::load(Artifacts::synthetic(0)?)?;
    bench_model(&mut bench, "tiny", &tiny);

    println!("\n== sized model (d=512, weights >> L2: the weight-traversal regime) ==");
    let sized = Engine::load(Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 2048,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?)?;
    let (at_1, at_8) = bench_model(&mut bench, "sized", &sized);

    let speedup = at_8 / at_1.max(f64::MIN_POSITIVE);
    println!(
        "\nbatched decode, synthetic sized model: batch 8 vs batch 1 = {speedup:.2}x \
         (one weight traversal serves 8 sessions; target >= 2x)"
    );
    Ok(())
}
