//! Bench for paper Fig. 5: tokens/second of PIM-LLM vs TPU-LLM across
//! all Table II models and context lengths 128..4096, with the paper's
//! stated speedups checked at the four annotated points (11.6x / 79.2x
//! at l=128; 1.5x / 5.71x at l=4096).
//!
//! Run: `cargo bench --bench fig5_tokens_per_sec`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, Arch};
use pim_llm::models;
use pim_llm::util::bench::{black_box, Bench};

fn main() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::fig5(&arch);
    report::print_fig5(&rows);
    println!();

    // Paper-vs-measured at the stated points.
    let mut worst: f64 = 0.0;
    for r in &rows {
        if let Some(ps) = r.paper_speedup {
            let rel = (r.speedup - ps).abs() / ps;
            worst = worst.max(rel);
            println!(
                "paper point {} l={}: measured {:.2}x vs paper {:.2}x ({:+.1}%)",
                r.model,
                r.context,
                r.speedup,
                ps,
                100.0 * (r.speedup / ps - 1.0)
            );
        }
    }
    assert!(worst < 0.15, "worst paper deviation {:.1}% >= 15%", 100.0 * worst);
    println!("shape OK: all stated speedups within 15%");
    println!();

    let mut b = Bench::default();
    b.run("fig5/full_sweep_7models_x6ctx_x2arch", || {
        black_box(figures::fig5(&arch))
    });
    let opt = models::by_name("OPT-6.7B").unwrap();
    b.run("fig5/single_point_hybrid_opt67b_l128", || {
        black_box(coordinator::simulate(&arch, &opt, 128, Arch::PimLlm))
    });
    b.run("fig5/single_point_baseline_opt67b_l128", || {
        black_box(coordinator::simulate(&arch, &opt, 128, Arch::TpuLlm))
    });
}
