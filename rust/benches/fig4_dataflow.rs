//! Bench for paper Fig. 4: total decode-step cycles on the 32x32
//! systolic array under OS / WS / IS dataflows, for every Table II
//! model. Prints the figure's bars and asserts OS wins (the paper's
//! design decision), then times both the analytical model and the
//! cycle-accurate wavefront stepper.
//!
//! Run: `cargo bench --bench fig4_dataflow`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::systolic::dataflow::{gemm_cycles, Dataflow};
use pim_llm::systolic::wavefront::simulate_gemm;
use pim_llm::util::bench::{black_box, Bench};

fn main() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::fig4(&arch);
    report::print_fig4(&rows);
    println!();

    // Shape: OS lowest for every model (why the paper picked OS).
    for model in rows.iter().map(|r| r.model.clone()).collect::<std::collections::BTreeSet<_>>() {
        let get = |df: &str| {
            rows.iter()
                .find(|r| r.model == model && r.dataflow == df)
                .unwrap()
                .cycles
        };
        assert!(get("OS") < get("WS") && get("OS") < get("IS"), "{model}");
    }
    println!("shape OK: OS < WS and OS < IS for all models");
    println!();

    let mut b = Bench::default();
    b.run("fig4/analytical_all_models", || black_box(figures::fig4(&arch)));
    b.run("fig4/analytical_single_gemm", || {
        black_box(gemm_cycles(4096, 4096, 1, 32, 32, Dataflow::OutputStationary))
    });
    b.run("fig4/wavefront_64x64x64", || {
        black_box(simulate_gemm(64, 64, 64, 32, 32, Dataflow::OutputStationary))
    });
}
