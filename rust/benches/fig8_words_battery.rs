//! Bench for paper Fig. 8: Words per Battery Life — tokens obtainable
//! from a 5 Wh (18,000 J) edge battery at 1.5 tokens/word, for both
//! architectures across all models/contexts. Paper-stated anchor points
//! (OPT-6.7B @128: 1.6M vs 1.4M; GPT2-350M @4096: 35M vs 20M; OPT-6.7B
//! @4096: 1.6M vs 1.2M) are printed as paper-vs-measured.
//!
//! Run: `cargo bench --bench fig8_words_battery`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::util::bench::{black_box, Bench};

fn main() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::fig8(&arch);
    report::print_fig8(&rows);
    println!();

    // Internal consistency: Fig. 8 must be a pure transform of Fig. 7.
    let f7 = figures::fig7(&arch);
    for (r8, r7) in rows.iter().zip(f7.iter()) {
        let want = 18_000.0 * r7.pim_llm_tokens_per_j / 1.5;
        assert!(
            (r8.pim_llm_words - want).abs() / want < 1e-9,
            "fig8 inconsistent with fig7 at {} l={}",
            r8.model,
            r8.context
        );
    }

    // Shape at the paper's anchor points: PIM-LLM ahead on OPT-6.7B @128
    // and the ordering PIM > TPU wherever fig7 gain is positive.
    for (r8, r7) in rows.iter().zip(f7.iter()) {
        if r7.gain_pct > 0.0 {
            assert!(r8.pim_llm_words > r8.tpu_llm_words);
        } else {
            assert!(r8.pim_llm_words <= r8.tpu_llm_words);
        }
    }
    for r in rows.iter().filter(|r| r.paper_pim_words.is_some()) {
        println!(
            "paper point {} l={}: measured {:.2}M/{:.2}M words vs paper {:.1}M/{:.1}M (PIM/TPU)",
            r.model,
            r.context,
            r.pim_llm_words / 1e6,
            r.tpu_llm_words / 1e6,
            r.paper_pim_words.unwrap() / 1e6,
            r.paper_tpu_words.unwrap() / 1e6,
        );
    }
    println!("shape OK: fig8 == transform(fig7), winners consistent");
    println!();

    let mut b = Bench::default();
    b.run("fig8/full_sweep", || black_box(figures::fig8(&arch)));
}
