//! Bench for the packed-bitplane backend: reference vs packed tokens/s
//! at batch 1/4/8, plus the bytes-per-weight table.
//!
//! The paper's PIM banks hold 1-bit (ternary) weights, not f32: a
//! projection MVM is sign-accumulate over 2-bit cells, and the weight
//! traffic per token is 16x smaller than the dense representation the
//! reference executor streams. The `packed` backend realizes exactly
//! that storage (two u64 bitplanes per matrix, `crate::quant`) with
//! popcount kernels whose outputs are bit-for-bit identical to the
//! reference — so every speedup measured here is pure representation,
//! zero numerics drift (`tests/packed_equivalence.rs` enforces it).
//!
//! Two synthetic models are measured:
//! * the tiny test model (d=32) — overhead-dominated, small win;
//! * a sized-up model (d=512, dense weights ~27 MB, far beyond L2;
//!   packed ~1.7 MB) — the weight-streaming regime where shrinking the
//!   stationary operand 16x pays off. The headline line reports packed
//!   vs reference tokens/s on this model (target: >= 2x).
//!
//! Also reported, per model: the bytes-per-weight table (dense f32 vs
//! 2-bitplane packed, ~16x smaller) and the measured weight sparsity
//! (fraction of zero ternary weights — `workload::ternary_sparsity`;
//! expected ~0.31 for BitNet-b1.58 quantized Gaussians, see
//! `workload::EXPECTED_TERNARY_SPARSITY`). Zero weights are exactly the
//! entries the packed kernels skip for free.
//!
//! Run: `cargo bench --bench runtime_packed`

use pim_llm::quant::PackedModel;
use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, BatchDecoder, Engine};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;
use pim_llm::workload::{
    is_ternary_param, ternary_sparsity, SparsityStats, EXPECTED_TERNARY_SPARSITY,
};

const BATCH_SIZES: [usize; 3] = [1, 4, 8];
const PROMPT_LEN: usize = 2;
const NEW_TOKENS: usize = 6;

/// Ragged-ish deterministic prompts for `b` sessions.
fn prompts(b: usize, vocab: usize) -> Vec<Vec<i32>> {
    (0..b)
        .map(|i| {
            (0..PROMPT_LEN)
                .map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32)
                .collect()
        })
        .collect()
}

/// tokens/s of the batched greedy loop at batch size `b`.
fn bench_engine(bench: &mut Bench, label: &str, engine: &Engine, b: usize) -> f64 {
    let ps = prompts(b, engine.vocab());
    let n_new = vec![NEW_TOKENS; b];
    let tokens = b * (PROMPT_LEN + NEW_TOKENS);
    let m = bench.run(&format!("{label}_b{b}"), || {
        let mut dec = BatchDecoder::new(engine);
        let t = dec.generate(&ps, &n_new).unwrap();
        black_box(t.steps)
    });
    tokens as f64 / m.mean_s
}

/// The bytes-per-weight and sparsity report for one model.
fn report_model(artifacts: &Artifacts) -> Result<()> {
    let packed = PackedModel::lower(artifacts)?;
    let dense = packed.dense_f32_bytes();
    let bits = packed.packed_bytes();
    let weights: usize = packed.matrices().iter().map(|(_, m)| m.k * m.n).sum();
    println!(
        "  weights: {} ternary entries in {} matrices",
        weights,
        packed.matrices().len()
    );
    println!(
        "  bytes/weight: dense f32 {:.2} ({:.1} KiB) | packed 2-bitplane {:.3} ({:.1} KiB) \
         | {:.1}x smaller",
        dense as f64 / weights as f64,
        dense as f64 / 1024.0,
        bits as f64 / weights as f64,
        bits as f64 / 1024.0,
        dense as f64 / bits as f64
    );
    // Measured sparsity from the dense source (the zoo-level stat) must
    // agree with the popcount census of the packed planes.
    let mut census = SparsityStats { zeros: 0, total: 0 };
    for p in &artifacts.manifest.params {
        if is_ternary_param(p) {
            census.merge(ternary_sparsity(artifacts.param_data(p)));
        }
    }
    println!(
        "  weight sparsity: measured {:.4} (planes census {:.4}, expected ~{EXPECTED_TERNARY_SPARSITY}) \
         — zero weights the packed kernels skip for free",
        census.fraction(),
        packed.sparsity()
    );
    Ok(())
}

/// Bench one model on both backends; returns (reference, packed)
/// tokens/s at the largest batch size.
fn bench_model(bench: &mut Bench, label: &str, artifacts: &Artifacts) -> Result<(f64, f64)> {
    report_model(artifacts)?;
    let reference = Engine::load_with(artifacts.clone(), BackendKind::Reference)?;
    let packed = Engine::load_with(artifacts.clone(), BackendKind::Packed)?;
    let (mut ref_last, mut packed_last) = (0.0, 0.0);
    for &b in &BATCH_SIZES {
        let r = bench_engine(bench, &format!("{label}/reference"), &reference, b);
        let p = bench_engine(bench, &format!("{label}/packed"), &packed, b);
        println!(
            "  {label}: batch {b:>2} -> reference {r:9.1} tok/s | packed {p:9.1} tok/s \
             | {:.2}x",
            p / r.max(f64::MIN_POSITIVE)
        );
        ref_last = r;
        packed_last = p;
    }
    Ok((ref_last, packed_last))
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== tiny model (d=32, overhead-dominated) ==");
    let tiny = Artifacts::synthetic(0)?;
    bench_model(&mut bench, "tiny", &tiny)?;

    println!("\n== sized model (d=512, dense weights >> L2: the weight-traffic regime) ==");
    let sized = Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 2048,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?;
    let (reference, packed) = bench_model(&mut bench, "sized", &sized)?;

    let speedup = packed / reference.max(f64::MIN_POSITIVE);
    println!(
        "\npacked backend, synthetic sized model (batch 8): {speedup:.2}x reference tokens/s \
         (identical bits, 16x less weight traffic; target >= 2x)"
    );
    Ok(())
}
