//! Bench for copy-on-write prefix sharing: tokens/s and prefill MACs
//! saved vs prefix-hit-rate (0% / 50% / 90%) at EQUAL arena capacity,
//! on the tiny and d=512 synthetic models.
//!
//! Workload: N requests with a long prompt and a short generation
//! budget — the prefill-dominated, shared-system-prompt regime the
//! ROADMAP's "millions of users" serving story lives in. At hit rate r,
//! `round(r * N)` requests carry one of two SYSTEM prompts that a
//! warm-up serve put in the index beforehand (how a production cache
//! reaches steady state); the rest are fully distinct. Every timed
//! iteration serves a FRESH stream (per-iteration salt on every
//! non-system token) so self-insertion during one iteration cannot turn
//! the next iteration's misses into hits — the measured hit rate stays
//! the configured one, and only the shared system prefixes are ever
//! reused. Same request shape, same continuous scheduler, same arena at
//! every rate: the only variable is how much prefill the cache absorbs.
//!
//! Tokens are asserted identical to a cache-off run (sharing is a
//! storage optimization, never a numerics change —
//! `tests/prefix_equivalence.rs` pins this bitwise); saved prefill MACs
//! are computed from the per-token projection MAC count (the paper's
//! PIM-side work: QKV + attention-out + FFN + head).
//!
//! Headline (ISSUE 5 acceptance): >= 2x prefill-token throughput at
//! 90% hit rate on the d=512 model vs the 0% baseline.
//!
//! Run: `cargo bench --bench runtime_prefix`

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, Engine};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;
use std::cell::Cell;

const N_REQUESTS: usize = 10;
const LANES: usize = 4;
const BLOCK_LEN: usize = 4;

/// Per-token projection MACs: QKV (3 d^2) + attention out (d^2) +
/// FFN in/out (2 d d_ff) per layer, plus the head (d * vocab).
fn projection_macs_per_token(m: &ModelInfo) -> usize {
    m.n_layers * (4 * m.d * m.d + 2 * m.d * m.d_ff) + m.d * m.vocab
}

/// One of the two warmed system-prompt token streams.
fn system_token(which: usize, j: usize, vocab: usize) -> i32 {
    ((which * 7919 + j * 13) % (vocab - 1) + 1) as i32
}

/// The request stream for one (hit count, salt): requests `0..hits`
/// share a warmed system prompt (distinct final token, so prefill
/// always runs >= 1 position); the rest are fully distinct. `salt`
/// varies every non-system token so streams from different iterations
/// never match each other in the index.
fn requests(hits: usize, salt: usize, p_len: usize, n_new: usize, vocab: usize) -> Vec<Request> {
    (0..N_REQUESTS as u64)
        .map(|id| {
            let i = id as usize;
            let prompt: Vec<i32> = (0..p_len)
                .map(|j| {
                    if i < hits && j + 1 < p_len {
                        system_token(i % 2, j, vocab)
                    } else {
                        let stream = (i + 3 + salt * 977) * 104_729 + j * 31;
                        (stream % (vocab - 1) + 1) as i32
                    }
                })
                .collect();
            Request { id, prompt, n_new }
        })
        .collect()
}

/// Warm-up requests: the two system prompts themselves.
fn warmup_requests(p_len: usize, n_new: usize, vocab: usize) -> Vec<Request> {
    (0..2u64)
        .map(|w| Request {
            id: 1000 + w,
            prompt: (0..p_len).map(|j| system_token(w as usize, j, vocab)).collect(),
            n_new,
        })
        .collect()
}

struct HitPoint {
    rate_pct: usize,
    tokens_per_s: f64,
    prefill_tokens_per_s: f64,
    saved_tokens: usize,
}

fn bench_model(bench: &mut Bench, label: &str, artifacts: &Artifacts) -> Result<Vec<HitPoint>> {
    let m = artifacts.manifest.model.clone();
    let p_len = (m.max_ctx * 3 / 4).min(m.max_ctx - 5);
    let n_new = 4usize;
    let macs_per_token = projection_macs_per_token(&m);
    // Equal arena capacity at every hit rate: the lanes' worst case
    // plus headroom for the warmed system chains' index pins.
    let blocks_each = (p_len + n_new).div_ceil(BLOCK_LEN);
    let capacity = blocks_each * (LANES + 3);
    let policy = Policy::Continuous { max_active: LANES };
    println!(
        "  {label}: {N_REQUESTS} requests x ({p_len} prompt + {n_new} new), \
         arena {capacity} blocks x {BLOCK_LEN} positions, \
         {macs_per_token} projection MACs/token"
    );

    // Cache-off engine: the token oracle (hits must change no token).
    let engine_off = Engine::load_with_arena(
        artifacts.clone(),
        BackendKind::Reference,
        BLOCK_LEN,
        capacity,
    )?;
    let mut points = Vec::new();
    for rate_pct in [0usize, 50, 90] {
        let hits = (rate_pct * N_REQUESTS).div_ceil(100);

        // Fresh warmed engine per hit rate; the warm-up serve is
        // untimed (a live deployment's steady-state index).
        let engine = Engine::load_with_arena(
            artifacts.clone(),
            BackendKind::Reference,
            BLOCK_LEN,
            capacity,
        )?;
        assert!(engine.enable_prefix_cache(0));
        Server::new(&engine, policy).serve(warmup_requests(p_len, n_new, m.vocab))?;

        // Untimed instrumented pass (salt 0): token contract against
        // the cache-off oracle, plus the saved-token count.
        let probe = requests(hits, 0, p_len, n_new, m.vocab);
        let golden = Server::new(&engine_off, Policy::Fifo).serve(probe.clone())?;
        let out = Server::new(&engine, policy).serve(probe)?;
        for g in &golden {
            let r = out.iter().find(|r| r.id == g.id).expect("response");
            assert_eq!(g.tokens, r.tokens, "hit rate {rate_pct}%: tokens changed");
        }
        let saved_tokens: usize = out.iter().map(|r| r.cached_tokens).sum();

        // Timed runs: each iteration serves a FRESH salted stream, so
        // only the warmed system prefixes can hit.
        let total_tokens = N_REQUESTS * (p_len + n_new);
        let prompt_tokens = N_REQUESTS * p_len;
        let salt = Cell::new(0usize);
        let measured = bench.run(&format!("{label}/hit{rate_pct}"), || {
            salt.set(salt.get() + 1);
            let reqs = requests(hits, salt.get(), p_len, n_new, m.vocab);
            black_box(Server::new(&engine, policy).serve(reqs).unwrap().len())
        });
        let tokens_per_s = total_tokens as f64 / measured.mean_s;
        let prefill_tokens_per_s = prompt_tokens as f64 / measured.mean_s;
        println!(
            "  {label}: hit {rate_pct:>2}% | {tokens_per_s:9.1} tok/s | \
             prefill {prefill_tokens_per_s:9.1} tok/s | {saved_tokens:>4} prompt \
             tokens cached/run ({:.2e} MACs saved)",
            (saved_tokens * macs_per_token) as f64
        );
        points.push(HitPoint {
            rate_pct,
            tokens_per_s,
            prefill_tokens_per_s,
            saved_tokens,
        });
    }
    Ok(points)
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== tiny model (d=32, overhead-dominated) ==");
    let tiny = Artifacts::synthetic(0)?;
    bench_model(&mut bench, "tiny", &tiny)?;

    println!("\n== sized model (d=512, weights >> L2: the weight-traversal regime) ==");
    let sized = Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 2048,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?;
    let points = bench_model(&mut bench, "sized", &sized)?;

    let base = points.iter().find(|p| p.rate_pct == 0).expect("0% point");
    let hot = points.iter().find(|p| p.rate_pct == 90).expect("90% point");
    println!(
        "\nprefix cache, d=512, 90% hit rate: {:.2}x prefill-token throughput and \
         {:.2}x total tokens/s vs 0% hits at equal arena capacity, {} prompt \
         positions served from cache per run (identical tokens; target >= 2x prefill)",
        hot.prefill_tokens_per_s / base.prefill_tokens_per_s.max(f64::MIN_POSITIVE),
        hot.tokens_per_s / base.tokens_per_s.max(f64::MIN_POSITIVE),
        hot.saved_tokens
    );
    Ok(())
}
