//! Bench for the int8 KV arena (`--kv-quant int8`): f32 vs int8 at
//! EQUAL ARENA BYTES, on the staggered, generation-heavy continuous
//! stream the serving story turns on.
//!
//! The claim being measured: an f32 block costs
//! `2 * block_floats * 4` bytes, an int8 block
//! `2 * (block_floats + groups * 4)` — ~3.9x denser at d_head 64 and
//! ~3.7x at this bench's shapes — so the SAME byte budget holds ~4x the
//! resident sessions. Under a capacity-constrained arena that is the
//! whole game for continuous batching: fewer preemptions, more lanes
//! actually occupied per weight traversal, more tokens/s from the same
//! memory. The decode itself pays a small dequant cost per attention
//! gather (int8 rows, i32 accumulation), so at a ROOMY arena int8 is
//! expected to be slightly slower — the bench reports both regimes.
//!
//! Outputs per (model, layout): sessions the arena can hold resident
//! (worst-case blocks per request), tokens/s, p95 service latency, and
//! preemptions. Headline: int8 resident sessions / f32 resident
//! sessions at equal bytes (target >= 3x), and the tokens/s ratio on
//! the pressured arena.
//!
//! Emits `BENCH_kvq.json` at the repo root.
//!
//! Run: `cargo bench --bench runtime_kvq`

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{ArenaLayout, Artifacts, BackendKind, CacheLayout, Engine};
use pim_llm::serving::{LatencyStats, Policy, Request, Server};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;
use std::time::Instant;

const LANES: usize = 8;
const N_REQUESTS: usize = 16;
const BLOCK_LEN: usize = 4;

/// Mixed-length, generation-heavy request stream (same shape as
/// `runtime_continuous`, so the two benches read side by side).
fn requests(vocab: usize) -> Vec<Request> {
    (0..N_REQUESTS as u64)
        .map(|id| {
            let i = id as usize;
            Request {
                id,
                prompt: (0..1 + i % 4)
                    .map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32)
                    .collect(),
                n_new: if i % 2 == 0 { 4 } else { 14 + (i % 4) * 2 },
            }
        })
        .collect()
}

struct Point {
    layout: &'static str,
    arena_blocks: usize,
    arena_bytes: usize,
    resident_sessions: usize,
    tokens_per_s: f64,
    p95_service_s: f64,
    evictions: usize,
}

fn serve_once(engine: &Engine, reqs: &[Request], offs: &[f64]) -> Result<(f64, f64, usize)> {
    let t0 = Instant::now();
    let out = Server::new(engine, Policy::Continuous { max_active: LANES })
        .serve_arrivals(reqs.to_vec(), offs)?;
    let wall = t0.elapsed().as_secs_f64();
    for r in &out {
        assert!(!r.tokens.is_empty(), "request {} produced no tokens", r.id);
    }
    let stats = LatencyStats::from_responses(&out, wall);
    Ok((stats.tokens_per_s, stats.p95_service_s, stats.evictions))
}

/// Bench one model at one byte budget under both layouts.
fn bench_model(bench: &mut Bench, label: &str, artifacts: &Artifacts) -> Result<Vec<Point>> {
    let reqs = requests(artifacts.manifest.model.vocab);
    let total_tokens: usize = reqs.iter().map(|r| r.prompt.len() + r.n_new).sum();
    let geometry = CacheLayout::with_block_len(&artifacts.manifest.model, BLOCK_LEN);
    let worst_blocks_each = reqs
        .iter()
        .map(|r| (r.prompt.len() + r.n_new).div_ceil(BLOCK_LEN))
        .max()
        .unwrap();
    // Byte budget: the f32 arena gets about a third of the stream's
    // worst-case reservation demand (the pressured regime of
    // `runtime_continuous`); the int8 arena gets the SAME bytes.
    let budget = (worst_blocks_each * LANES / 3) * geometry.block_bytes(ArenaLayout::F32);
    println!(
        "  {label}: {} requests, {total_tokens} tokens, byte budget {budget} \
         (worst case {worst_blocks_each} blocks/request, {LANES} lanes)",
        reqs.len(),
    );

    // Stagger calibration on a roomy engine, shared by both layouts so
    // the arrival shape is identical.
    let roomy = Engine::load_with_arena(
        artifacts.clone(),
        BackendKind::Reference,
        BLOCK_LEN,
        worst_blocks_each * LANES,
    )?;
    let t0 = Instant::now();
    Server::new(&roomy, Policy::Fifo).serve(vec![reqs[0].clone()])?;
    let per_token =
        t0.elapsed().as_secs_f64() / (reqs[0].prompt.len() + reqs[0].n_new) as f64;
    let offs: Vec<f64> = (0..reqs.len()).map(|i| i as f64 * per_token * 2.0).collect();
    drop(roomy);

    let mut points = Vec::new();
    for mode in [ArenaLayout::F32, ArenaLayout::KvInt8] {
        let blocks = geometry.blocks_for_bytes(budget, mode);
        let engine = Engine::load_with_arena_mode(
            artifacts.clone(),
            BackendKind::Reference,
            BLOCK_LEN,
            blocks,
            mode,
        )?;
        let st = engine.arena_status();
        let resident = blocks / worst_blocks_each;
        let (_, p95, evict) = serve_once(&engine, &reqs, &offs)?;
        let m = bench.run(&format!("{label}/kv_{}", mode.name()), || {
            black_box(serve_once(&engine, &reqs, &offs).unwrap())
        });
        let tps = total_tokens as f64 / m.mean_s;
        println!(
            "  {label}: kv={:4} arena {blocks:3} blocks = {} bytes | {resident} resident \
             sessions | {tps:9.1} tok/s | p95 {p95:7.3}s | {evict} preemptions",
            mode.name(),
            st.total_bytes,
        );
        points.push(Point {
            layout: mode.name(),
            arena_blocks: blocks,
            arena_bytes: st.total_bytes,
            resident_sessions: resident,
            tokens_per_s: tps,
            p95_service_s: p95,
            evictions: evict,
        });
    }
    Ok(points)
}

fn json_points(points: &[Point]) -> String {
    points
        .iter()
        .map(|p| {
            format!(
                "    {{\"layout\": \"{}\", \"arena_blocks\": {}, \"arena_bytes\": {}, \
                 \"resident_sessions\": {}, \"tokens_per_s\": {:.1}, \
                 \"p95_service_s\": {:.4}, \"evictions\": {}}}",
                p.layout,
                p.arena_blocks,
                p.arena_bytes,
                p.resident_sessions,
                p.tokens_per_s,
                p.p95_service_s,
                p.evictions
            )
        })
        .collect::<Vec<_>>()
        .join(",\n")
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== tiny model (d=32, overhead-dominated) ==");
    let tiny = Artifacts::synthetic(0)?;
    let tiny_points = bench_model(&mut bench, "tiny", &tiny)?;

    println!("\n== sized model (d=512, weights >> L2: the weight-traversal regime) ==");
    let sized = Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 2048,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?;
    let sized_points = bench_model(&mut bench, "sized", &sized)?;

    let find = |pts: &[Point], l: &str| pts.iter().find(|p| p.layout == l).unwrap();
    let (f, q) = (find(&sized_points, "f32"), find(&sized_points, "int8"));
    let density = q.resident_sessions as f64 / (f.resident_sessions as f64).max(1.0);
    println!(
        "\nint8 KV arena at equal bytes, sized model: {density:.2}x resident sessions \
         ({} vs {}), {:.2}x tokens/s, preemptions {} vs {} (target >= 3x sessions)",
        q.resident_sessions,
        f.resident_sessions,
        q.tokens_per_s / f.tokens_per_s.max(f64::MIN_POSITIVE),
        q.evictions,
        f.evictions,
    );
    assert!(
        q.resident_sessions >= 3 * f.resident_sessions.max(1),
        "int8 must fit >= 3x the sessions at equal bytes \
         ({} vs {})",
        q.resident_sessions,
        f.resident_sessions
    );

    let json = format!(
        "{{\n  \"bench\": \"runtime_kvq\",\n  \"block_len\": {BLOCK_LEN},\n  \
         \"lanes\": {LANES},\n  \"requests\": {N_REQUESTS},\n  \
         \"sessions_ratio_sized\": {density:.3},\n  \"tiny\": [\n{}\n  ],\n  \
         \"sized\": [\n{}\n  ]\n}}\n",
        json_points(&tiny_points),
        json_points(&sized_points)
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kvq.json");
    std::fs::write(path, &json)
        .map_err(|e| pim_llm::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}
