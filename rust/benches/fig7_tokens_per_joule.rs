//! Bench for paper Fig. 7: tokens per joule of PIM-LLM vs TPU-LLM.
//!
//! The qualitative shape the paper reports and we check:
//!   * TPU-LLM is MORE energy-efficient for the smallest model (GPT2-
//!     355M) at short context (paper: 33.7% lower energy at l=128).
//!   * PIM-LLM crosses over around OPT-1.3B at l=128 (+0.96%) and the
//!     gain grows with model size (+12.49% for OPT-6.7B).
//!
//! The paper also reports gains *growing* with context length for fixed
//! small models (+70.58% GPT2-350M @4096). Our component-energy analysis
//! shows that trend is not derivable from any time-invariant component
//! model (both architectures execute identical attention ops); see
//! EXPERIMENTS.md §Fig.7 for the full derivation. We therefore check the
//! model-size crossover strictly and report the context trend as
//! paper-vs-measured without asserting it.
//!
//! Run: `cargo bench --bench fig7_tokens_per_joule`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::util::bench::{black_box, Bench};

fn gain(rows: &[figures::Fig7Row], model: &str, l: usize) -> f64 {
    rows.iter()
        .find(|r| r.model == model && r.context == l)
        .unwrap()
        .gain_pct
}

fn main() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::fig7(&arch);
    report::print_fig7(&rows);
    println!();

    // Crossover shape at l=128 (strict checks).
    let g_gpt = gain(&rows, "GPT2-355M", 128);
    let g_13 = gain(&rows, "OPT-1.3B", 128);
    let g_67 = gain(&rows, "OPT-6.7B", 128);
    println!("l=128 gains: GPT2-355M {g_gpt:+.1}% | OPT-1.3B {g_13:+.1}% | OPT-6.7B {g_67:+.1}%");
    assert!(g_gpt < 0.0, "TPU-LLM must win on GPT2-355M @128 (paper: by 33.7%)");
    assert!(g_13 > g_gpt, "gain must grow with model size");
    assert!(g_67 > g_13, "gain must grow with model size");
    assert!(g_67 > 0.0, "PIM-LLM must win on OPT-6.7B @128 (paper: +12.49%)");

    // Context-length trend: report paper-vs-measured.
    for (model, l) in [("GPT2-355M", 2048usize), ("GPT2-355M", 4096), ("OPT-6.7B", 2048), ("OPT-6.7B", 4096)] {
        let r = rows
            .iter()
            .find(|r| r.model == model && r.context == l)
            .unwrap();
        println!(
            "paper point {model} l={l}: measured {:+.1}% vs paper {:+.1}%",
            r.gain_pct,
            r.paper_gain_pct.unwrap()
        );
    }
    println!("shape OK: crossover at/above OPT-1.3B, monotone in model size");
    println!();

    let mut b = Bench::default();
    b.run("fig7/full_energy_sweep", || black_box(figures::fig7(&arch)));
}
