//! Bench for paper Table III: GOPS and GOPS/W of PIM-LLM vs prior PIM
//! language-model accelerators (TransPIM, HARDSEA — literature values,
//! as the paper itself uses).
//!
//! Paper claims checked:
//!   * >= 2x GOPS vs HARDSEA on GPT2-Small @ l=1024 (3.2 -> 6.47 GOPS).
//!   * >= 5x GOPS/W vs TransPIM on GPT2-Medium @ l=4096 (<200 -> 1026).
//!   * OPT-6.7B headline points: 58.5 GOPS @1024, 17.6 GOPS @4096.
//!
//! Run: `cargo bench --bench table3_gops`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::util::bench::{black_box, Bench};

fn main() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::table3(&arch);
    report::print_table3(&rows);
    println!();

    let ours = |model: &str, l: usize| {
        rows.iter()
            .find(|r| r.design.contains("ours") && r.model == model && r.context == l)
            .unwrap()
    };

    // GOPS vs paper at the four stated points (within 25% — GOPS depends
    // on the full latency model).
    for (model, l) in [
        ("GPT2-Small", 1024usize),
        ("GPT2-Medium", 4096),
        ("OPT-6.7B", 1024),
        ("OPT-6.7B", 4096),
    ] {
        let r = ours(model, l);
        let got = r.gops.unwrap();
        let want = r.paper_gops.unwrap();
        println!(
            "paper point {model} l={l}: measured {got:.2} GOPS vs paper {want:.2} ({:+.1}%)",
            100.0 * (got / want - 1.0)
        );
        assert!(
            (got - want).abs() / want < 0.25,
            "{model} l={l}: {got:.2} vs {want:.2}"
        );
    }

    // Headline comparisons.
    let vs_hardsea = ours("GPT2-Small", 1024).gops.unwrap() / 3.2;
    println!("GOPS vs HARDSEA: {vs_hardsea:.2}x (paper claims 2x)");
    assert!(vs_hardsea > 1.6, "must beat HARDSEA by ~2x");

    let gpw = ours("GPT2-Medium", 4096).gops_per_w.unwrap();
    println!("GOPS/W vs TransPIM(<200): {:.0} ({:.1}x, paper claims 5x)", gpw, gpw / 200.0);
    assert!(gpw > 2.0 * 200.0, "must clearly beat TransPIM's 200 GOPS/W");
    println!("shape OK: Table III wins reproduced");
    println!();

    let mut b = Bench::default();
    b.run("table3/generate", || black_box(figures::table3(&arch)));
}
