//! Ablation benches for the design choices the paper calls out:
//!
//! 1. **Attention-on-PIM** (what the paper refuses to do, §III): write
//!    K/V into crossbars each token -> write latency/energy per token and
//!    device lifetime at the achieved token rate. Shows why the hybrid
//!    split exists.
//! 2. **Crossbar size** (128 / 256 / 512): how the paper's 256x256 choice
//!    trades communication (more crossbars to collect) against analog
//!    step granularity.
//! 3. **ADC sharing ratio** (4 / 8 / 16 columns per ADC): digitization
//!    throughput vs ADC area/energy.
//! 4. **Dataflow choice on the attention ops only** (the hybrid's TPU
//!    side): confirms OS also wins restricted to W8A8 ops.
//!
//! Run: `cargo bench --bench ablations`

use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, Arch};
use pim_llm::models;
use pim_llm::pim::writes;
use pim_llm::systolic::dataflow::Dataflow;
use pim_llm::systolic::run_op;
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::workload;

fn main() {
    let base = ArchConfig::paper_45nm();
    let opt = models::by_name("OPT-6.7B").unwrap();

    // ---------------------------------------------- 1. attention-on-PIM
    println!("== ablation 1: attention-on-PIM (the rejected design) ==");
    let hybrid = coordinator::simulate(&base, &opt, 128, Arch::PimLlm);
    let tokens_per_s = 1.0 / hybrid.latency_s();
    let cost = writes::attention_on_pim(&base.pim, opt.d, opt.n_layers, tokens_per_s);
    println!(
        "OPT-6.7B: +{:.3} ms write latency/token (vs {:.3} ms hybrid token), \
         +{:.3} mJ write energy/token, device lifetime {:.1} days at {:.1} tok/s",
        1e3 * cost.write_latency_s,
        1e3 * hybrid.latency_s(),
        1e3 * cost.write_energy_j,
        cost.lifetime_s / 86_400.0,
        tokens_per_s
    );
    assert!(
        cost.lifetime_s < 365.0 * 86_400.0,
        "endurance death in under a year justifies the hybrid split"
    );

    // ------------------------------------------------- 2. crossbar size
    println!("\n== ablation 2: crossbar size (communication vs granularity) ==");
    for dim in [128usize, 256, 512] {
        let mut arch = base.clone();
        arch.pim.crossbar_dim = dim;
        let r = coordinator::simulate(&arch, &opt, 128, Arch::PimLlm);
        println!(
            "dim {dim:>4}: token latency {:.3} ms (comm {:.3} ms = {:.1}%)",
            1e3 * r.latency_s(),
            1e3 * r.breakdown.communication_s,
            100.0 * r.breakdown.communication_s / r.latency_s()
        );
    }
    // Bigger crossbars -> fewer to collect -> less communication.
    let comm = |dim: usize| {
        let mut arch = base.clone();
        arch.pim.crossbar_dim = dim;
        coordinator::simulate(&arch, &opt, 128, Arch::PimLlm)
            .breakdown
            .communication_s
    };
    assert!(comm(512) < comm(256) && comm(256) < comm(128));

    // --------------------------------------------------- 3. ADC sharing
    println!("\n== ablation 3: ADC sharing ratio ==");
    for share in [4usize, 8, 16] {
        let mut arch = base.clone();
        arch.pim.adc_share = share;
        let r = coordinator::simulate(&arch, &opt, 128, Arch::PimLlm);
        println!(
            "share {share:>3}: pim analog {:.3} us/step-chain, token latency {:.3} ms",
            1e6 * r.breakdown.pim_analog_s(),
            1e3 * r.latency_s()
        );
    }

    // ------------------------------------- 4. dataflow on attention ops
    println!("\n== ablation 4: dataflow restricted to attention ops ==");
    let ops = workload::decode_ops(&opt, 1024);
    for df in Dataflow::ALL {
        let cycles: u64 = ops
            .iter()
            .filter(|o| o.is_attention())
            .map(|o| run_op(&base.tpu, o, df).cycles)
            .sum();
        println!("{}: {} cycles", df.short_name(), cycles);
    }
    let att_cycles = |df: Dataflow| -> u64 {
        ops.iter()
            .filter(|o| o.is_attention())
            .map(|o| run_op(&base.tpu, o, df).cycles)
            .sum()
    };
    assert!(att_cycles(Dataflow::OutputStationary) < att_cycles(Dataflow::WeightStationary));
    assert!(att_cycles(Dataflow::OutputStationary) < att_cycles(Dataflow::InputStationary));
    println!("\nshape OK: all four ablations support the paper's choices");
    println!();

    let mut b = Bench::default();
    b.run("ablations/crossbar_size_sweep", || {
        for dim in [128usize, 256, 512] {
            let mut arch = base.clone();
            arch.pim.crossbar_dim = dim;
            black_box(coordinator::simulate(&arch, &opt, 128, Arch::PimLlm));
        }
    });
}
