//! Bench for the continuous-batching serving policy: tokens/s and p95
//! end-to-end latency, fixed-wave `Batched` vs `Continuous`, on a
//! staggered-arrival, mixed-length request stream at EQUAL arena
//! capacity.
//!
//! The comparison the paper's serving story turns on: fixed-wave
//! batching reserves every request's worst-case KV-cache blocks at
//! admission, so a capacity-constrained arena caps its concurrency at
//! "how many worst cases fit"; continuous batching claims blocks on
//! demand (preempting the youngest session under pressure), so the same
//! arena sustains more concurrent sessions — and with one weight
//! traversal per tick regardless of batch width, more sessions per tick
//! is directly more tokens per traversal. Both policies produce
//! IDENTICAL tokens (asserted here and enforced by
//! `tests/paged_equivalence.rs`); the delta is pure scheduling.
//!
//! Workload: generation-heavy requests (short prompts, mixed short/long
//! generation budgets) arriving staggered over time (the stagger is
//! calibrated from a measured per-token cost so the shape survives
//! machine-speed differences), against an arena sized to roughly a
//! third of the stream's worst-case reservation demand.
//!
//! Two synthetic models are measured: the tiny test model (d=32) and
//! the d=512 sized model whose weights dwarf L2 (the weight-traversal
//! regime — same sizing as `runtime_batching`). Headline: continuous
//! tokens/s vs batched tokens/s on the sized model (target: > 1x,
//! i.e. strictly higher at equal arena capacity).
//!
//! Run: `cargo bench --bench runtime_continuous`

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, Engine};
use pim_llm::serving::{LatencyStats, Policy, Request, Server};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;
use std::time::Instant;

const LANES: usize = 8;
const N_REQUESTS: usize = 16;
const BLOCK_LEN: usize = 4;

/// Mixed-length, generation-heavy request stream: short prompts (1-4
/// tokens), alternating short (4) and long (14-20) generation budgets.
fn requests(vocab: usize) -> Vec<Request> {
    (0..N_REQUESTS as u64)
        .map(|id| {
            let i = id as usize;
            Request {
                id,
                prompt: (0..1 + i % 4)
                    .map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32)
                    .collect(),
                n_new: if i % 2 == 0 { 4 } else { 14 + (i % 4) * 2 },
            }
        })
        .collect()
}

/// Arrival offsets: request `i` arrives at `i * stagger` seconds.
fn offsets(n: usize, stagger: f64) -> Vec<f64> {
    (0..n).map(|i| i as f64 * stagger).collect()
}

/// Serve the stream once and report (tokens/s, p95 service latency,
/// preemptions), asserting the token contract against a reference.
fn serve_once(
    engine: &Engine,
    policy: Policy,
    reqs: &[Request],
    offs: &[f64],
    reference_tokens: Option<&[(u64, Vec<i32>)]>,
) -> Result<(f64, f64, usize)> {
    let t0 = Instant::now();
    let out = Server::new(engine, policy).serve_arrivals(reqs.to_vec(), offs)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = LatencyStats::from_responses(&out, wall);
    if let Some(want) = reference_tokens {
        for (id, tokens) in want {
            let got = out.iter().find(|r| r.id == *id).expect("response");
            assert_eq!(&got.tokens, tokens, "request {id}: policies must agree");
        }
    }
    Ok((stats.tokens_per_s, stats.p95_service_s, stats.evictions))
}

/// Bench one model at equal arena capacity under both policies; returns
/// (batched tok/s, continuous tok/s) from the timed runs.
fn bench_model(bench: &mut Bench, label: &str, artifacts: &Artifacts) -> Result<(f64, f64)> {
    let reqs = requests(artifacts.manifest.model.vocab);
    let total_tokens: usize = reqs.iter().map(|r| r.prompt.len() + r.n_new).sum();
    // Arena: about a third of the stream's worst-case block demand at
    // LANES concurrency — tight enough that reservations throttle the
    // fixed-wave scheduler while on-demand paging keeps packing.
    let worst_blocks_each = reqs
        .iter()
        .map(|r| (r.prompt.len() + r.n_new).div_ceil(BLOCK_LEN))
        .max()
        .unwrap();
    let capacity = (worst_blocks_each * LANES) / 3;
    let engine = Engine::load_with_arena(
        artifacts.clone(),
        BackendKind::Reference,
        BLOCK_LEN,
        capacity,
    )?;
    println!(
        "  {label}: {} requests, {} tokens, arena {} blocks x {} positions \
         (worst case {} blocks/request, {} lanes)",
        reqs.len(),
        total_tokens,
        capacity,
        BLOCK_LEN,
        worst_blocks_each,
        LANES
    );

    // Calibrate the arrival stagger to ~2 tokens of measured decode time
    // so the arrival shape is machine-speed independent.
    let t0 = Instant::now();
    Server::new(&engine, Policy::Fifo).serve(vec![reqs[0].clone()])?;
    let per_token = t0.elapsed().as_secs_f64()
        / (reqs[0].prompt.len() + reqs[0].n_new) as f64;
    let stagger = per_token * 2.0;
    let offs = offsets(reqs.len(), stagger);

    // Token contract + instrumented stats from one untimed run each.
    let golden: Vec<(u64, Vec<i32>)> = Server::new(&engine, Policy::Fifo)
        .serve(reqs.clone())?
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    let batched = Policy::Batched { batch: LANES };
    let continuous = Policy::Continuous { max_active: LANES };
    let (_, b_p95, b_evict) = serve_once(&engine, batched, &reqs, &offs, Some(&golden))?;
    let (_, c_p95, c_evict) = serve_once(&engine, continuous, &reqs, &offs, Some(&golden))?;

    // Timed runs.
    let mb = bench.run(&format!("{label}/batched_w{LANES}"), || {
        black_box(serve_once(&engine, batched, &reqs, &offs, None).unwrap())
    });
    let mc = bench.run(&format!("{label}/continuous_w{LANES}"), || {
        black_box(serve_once(&engine, continuous, &reqs, &offs, None).unwrap())
    });
    let b_tps = total_tokens as f64 / mb.mean_s;
    let c_tps = total_tokens as f64 / mc.mean_s;
    println!(
        "  {label}: batched    {b_tps:9.1} tok/s | p95 {b_p95:7.3}s | {b_evict} preemptions"
    );
    println!(
        "  {label}: continuous {c_tps:9.1} tok/s | p95 {c_p95:7.3}s | {c_evict} preemptions \
         | {:.2}x batched",
        c_tps / b_tps.max(f64::MIN_POSITIVE)
    );
    Ok((b_tps, c_tps))
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== tiny model (d=32, overhead-dominated) ==");
    let tiny = Artifacts::synthetic(0)?;
    bench_model(&mut bench, "tiny", &tiny)?;

    println!("\n== sized model (d=512, weights >> L2: the weight-traversal regime) ==");
    let sized = Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 2048,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?;
    let (batched, continuous) = bench_model(&mut bench, "sized", &sized)?;

    println!(
        "\ncontinuous batching, staggered mixed-length stream, equal arena capacity: \
         {:.2}x fixed-wave batched tokens/s on the sized model \
         (identical tokens; target > 1x)",
        continuous / batched.max(f64::MIN_POSITIVE)
    );
    Ok(())
}
