//! Bench for paper Fig. 6: per-component latency percentage breakdown of
//! the hybrid PIM-LLM architecture at l=128 and l=4096, checked against
//! the percentages the paper states in §IV-B (systolic 60% / 73.9% at
//! l=128, >97% at l=4096; communication 36.3% / 10.7%; buffer 3.5% /
//! 14.7%; Xbar+DAC+ADC < 1%; peripheral < 0.01%).
//!
//! Run: `cargo bench --bench fig6_breakdown`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, Arch};
use pim_llm::models;
use pim_llm::util::bench::{black_box, Bench};

fn pct(rows: &[figures::Fig6Row], model: &str, l: usize, comp: &str) -> f64 {
    rows.iter()
        .find(|r| r.model == model && r.context == l)
        .unwrap()
        .percents
        .iter()
        .find(|(k, _)| k == comp)
        .unwrap()
        .1
}

fn main() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::fig6(&arch);
    report::print_fig6(&rows);
    println!();

    // Paper-vs-measured on the stated reference points.
    let checks = [
        ("OPT-6.7B", 128usize, "systolic", 60.0, 12.0),
        ("GPT2-355M", 128, "systolic", 73.9, 12.0),
        ("OPT-6.7B", 128, "communication", 36.3, 12.0),
        ("GPT2-355M", 128, "communication", 10.7, 6.0),
        ("GPT2-355M", 128, "buffer", 14.7, 6.0),
        ("OPT-6.7B", 128, "buffer", 3.5, 3.0),
    ];
    for (model, l, comp, paper, tol) in checks {
        let got = pct(&rows, model, l, comp);
        println!(
            "paper point {model} l={l} {comp}: measured {got:.1}% vs paper {paper:.1}%"
        );
        assert!(
            (got - paper).abs() < tol,
            "{model} l={l} {comp}: {got:.1}% vs paper {paper:.1}% (tol {tol})"
        );
    }
    // At l=4096 the systolic array dominates (> 90%, paper says > 97%).
    for model in ["GPT2-355M", "OPT-6.7B"] {
        let got = pct(&rows, model, 4096, "systolic");
        assert!(got > 90.0, "{model} @4096 systolic {got:.1}%");
        println!("paper point {model} l=4096 systolic: measured {got:.1}% vs paper >97%");
    }
    // PIM analog path (xbar+dac+adc) below 1%, peripheral below 0.01%.
    for model in ["GPT2-355M", "OPT-6.7B"] {
        let analog = pct(&rows, model, 128, "xbar")
            + pct(&rows, model, 128, "dac")
            + pct(&rows, model, 128, "adc");
        assert!(analog < 1.0, "{model} analog {analog:.3}%");
        assert!(pct(&rows, model, 128, "peripheral") < 0.01);
    }
    println!("shape OK: all Fig.6 reference points reproduced");
    println!();

    let mut b = Bench::default();
    b.run("fig6/breakdown_all_models_two_contexts", || {
        black_box(figures::fig6(&arch))
    });
    let m = models::by_name("OPT-6.7B").unwrap();
    b.run("fig6/single_breakdown_opt67b_l4096", || {
        black_box(coordinator::simulate(&arch, &m, 4096, Arch::PimLlm))
    });
}
