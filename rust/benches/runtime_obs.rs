//! Bench for the observability layer: tokens/s with tracing + metrics
//! ON vs OFF, at decode batch widths 1 and 8, on the packed backend
//! (the production hot path, where kernel spans fire 14 ring records
//! per layer per tick on top of the serving events).
//!
//! What is being isolated: the cost of a fully enabled [`pim_llm::obs`]
//! pipeline — one relaxed gate load, one monotonic clock read, and one
//! 40-byte slot write under an uncontended mutex per record — against
//! the identical serve with the gate closed. The ring is sized large
//! enough (default capacity) that no drain happens inside the timed
//! region; draining is an explicitly out-of-band operation.
//!
//! Both runs must produce byte-identical token streams (asserted every
//! iteration against the untraced oracle — the determinism suites pin
//! the same contract exhaustively). Headline: overhead at batch 1 and
//! batch 8 on the sized model, target < 3% tokens/s regression.
//!
//! Emits `BENCH_obs.json` at the repo root.
//!
//! Run: `cargo bench --bench runtime_obs`

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, Engine};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::bench::{black_box, Bench};
use pim_llm::util::error::Result;
use std::time::Instant;

const BATCH_WIDTHS: [usize; 2] = [1, 8];
const N_REQUESTS: usize = 8;
const BLOCK_LEN: usize = 4;
const ARENA_BLOCKS: usize = 64;

/// Generation-heavy stream: one request per lane at the widest batch,
/// short prompts so decode ticks (the instrumented steady state)
/// dominate over prefill.
fn requests(vocab: usize) -> Vec<Request> {
    (0..N_REQUESTS as u64)
        .map(|id| {
            let i = id as usize;
            Request {
                id,
                prompt: (0..1 + i % 3)
                    .map(|j| ((i * 31 + j * 7) % (vocab - 1) + 1) as i32)
                    .collect(),
                n_new: 12 + (i % 3) * 2,
            }
        })
        .collect()
}

struct Point {
    batch: usize,
    tokens_per_s_off: f64,
    tokens_per_s_on: f64,
    overhead_pct: f64,
    events_per_run: usize,
}

/// One serve on a fresh engine; `traced` flips the whole obs pipeline.
/// Returns (wall seconds, sorted token streams, events recorded).
fn serve_once(
    artifacts: &Artifacts,
    max_active: usize,
    traced: bool,
    reqs: &[Request],
) -> Result<(f64, Vec<(u64, Vec<i32>)>, usize)> {
    let engine = Engine::load_with_arena(
        artifacts.clone(),
        BackendKind::Packed,
        BLOCK_LEN,
        ARENA_BLOCKS,
    )?;
    if traced {
        engine.obs().set_enabled(true);
    }
    let t0 = Instant::now();
    let out = Server::new(&engine, Policy::Continuous { max_active }).serve(reqs.to_vec())?;
    let wall = t0.elapsed().as_secs_f64();
    let mut streams: Vec<(u64, Vec<i32>)> =
        out.into_iter().map(|r| (r.id, r.tokens)).collect();
    streams.sort_by_key(|(id, _)| *id);
    let events = engine.obs().trace.len() + engine.obs().trace.dropped() as usize;
    Ok((wall, streams, events))
}

fn bench_batch(bench: &mut Bench, artifacts: &Artifacts, batch: usize) -> Result<Point> {
    let reqs = requests(artifacts.manifest.model.vocab);
    let total_tokens: usize = reqs.iter().map(|r| r.prompt.len() + r.n_new).sum();

    // Inertness check once, untimed: traced tokens == untraced tokens.
    let (_, oracle, _) = serve_once(artifacts, batch, false, &reqs)?;
    let (_, traced_streams, events) = serve_once(artifacts, batch, true, &reqs)?;
    assert_eq!(oracle, traced_streams, "batch {batch}: tracing changed a token");
    assert!(events > 0, "batch {batch}: traced run recorded nothing");

    let off = bench.run(&format!("obs_off/b{batch}"), || {
        black_box(serve_once(artifacts, batch, false, &reqs).unwrap())
    });
    let on = bench.run(&format!("obs_on/b{batch}"), || {
        black_box(serve_once(artifacts, batch, true, &reqs).unwrap())
    });
    let tps_off = total_tokens as f64 / off.mean_s;
    let tps_on = total_tokens as f64 / on.mean_s;
    let overhead_pct = 100.0 * (1.0 - tps_on / tps_off);
    println!(
        "  batch {batch}: off {tps_off:9.1} tok/s | on {tps_on:9.1} tok/s | \
         overhead {overhead_pct:+5.2}% | {events} events/run"
    );
    Ok(Point {
        batch,
        tokens_per_s_off: tps_off,
        tokens_per_s_on: tps_on,
        overhead_pct,
        events_per_run: events,
    })
}

fn main() -> Result<()> {
    let mut bench = Bench::quick();

    println!("== sized model (d=512, d_ff=1536), packed backend, tracing off vs on ==");
    let sized = Artifacts::synthetic_with(
        0,
        ModelInfo {
            vocab: 512,
            d: 512,
            h: 8,
            d_ff: 1536,
            n_layers: 2,
            max_ctx: 32,
            eps: 1e-5,
        },
    )?;
    let mut points = Vec::new();
    for batch in BATCH_WIDTHS {
        points.push(bench_batch(&mut bench, &sized, batch)?);
    }

    let worst = points
        .iter()
        .map(|p| p.overhead_pct)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nfully enabled tracing + metrics: worst-case overhead {worst:+.2}% tokens/s \
         (target < 3%; identical tokens both ways)"
    );

    let body = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"batch\": {}, \"tokens_per_s_off\": {:.1}, \
                 \"tokens_per_s_on\": {:.1}, \"overhead_pct\": {:.3}, \
                 \"events_per_run\": {}}}",
                p.batch, p.tokens_per_s_off, p.tokens_per_s_on, p.overhead_pct,
                p.events_per_run
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let json = format!(
        "{{\n  \"bench\": \"runtime_obs\",\n  \"backend\": \"packed\",\n  \
         \"block_len\": {BLOCK_LEN},\n  \"arena_blocks\": {ARENA_BLOCKS},\n  \
         \"requests\": {N_REQUESTS},\n  \"target_overhead_pct\": 3.0,\n  \
         \"worst_overhead_pct\": {worst:.3},\n  \"points\": [\n{body}\n  ]\n}}\n"
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_obs.json");
    std::fs::write(path, &json)
        .map_err(|e| pim_llm::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}
