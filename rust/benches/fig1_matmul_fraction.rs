//! Bench for paper Fig. 1b: percentage of low-precision (W1A8) MatMul
//! operations across OPT models and context lengths. Prints the figure's
//! series and times the generator.
//!
//! Run: `cargo bench --bench fig1_matmul_fraction`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;
use pim_llm::util::bench::{black_box, Bench};

fn main() {
    let arch = ArchConfig::paper_45nm();

    let rows = figures::fig1b(&arch);
    report::print_fig1b(&rows);
    println!();

    // Shape assertions (the figure's claims).
    let opt350_4096 = rows
        .iter()
        .find(|r| r.model == "OPT-350M" && r.context == 4096)
        .expect("point exists");
    assert!(
        opt350_4096.low_precision_pct < 70.0,
        "OPT-350M @4096 must be the evenly-distributed case"
    );
    for r in rows.iter().filter(|r| r.context == 128) {
        if r.model != "OPT-350M" {
            assert!(r.low_precision_pct > 95.0, "{}: {}", r.model, r.low_precision_pct);
        }
    }
    // "more than 99%" holds for the largest model at short context.
    let opt67_128 = rows
        .iter()
        .find(|r| r.model == "OPT-6.7B" && r.context == 128)
        .unwrap();
    assert!(opt67_128.low_precision_pct > 99.0);
    println!(
        "shape OK: OPT-350M@4096 = {:.1}% (evenly split), OPT-6.7B@128 = {:.2}% (>99%)",
        opt350_4096.low_precision_pct, opt67_128.low_precision_pct
    );
    println!();

    let mut b = Bench::default();
    b.run("fig1b/generate_all_points", || black_box(figures::fig1b(&arch)));
}
