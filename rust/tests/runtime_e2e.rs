//! End-to-end runtime tests: artifacts -> backend -> token generation
//! -> serving, the full functional path of the system. Run offline on
//! the synthetic tiny model (reference backend); when real AOT
//! artifacts exist (`make artifacts`), they are exercised too.

use pim_llm::runtime::{artifacts, decoder, Artifacts, Engine, TinyDecoder};
use pim_llm::serving::{serve_threaded_with, LatencyStats, Policy, Request, Server};

const SEED: u64 = 0xE2E;

fn engine() -> Engine {
    Engine::load(Artifacts::synthetic(SEED).expect("synthetic artifacts")).expect("engine")
}

#[test]
fn golden_generation_token_for_token() {
    let e = engine();
    decoder::validate_golden(&e).expect("runtime must reproduce the recorded golden generation");
}

#[test]
fn real_artifacts_golden_if_present() {
    // With `make artifacts` output checked out, exercise the real AOT
    // decoder too; skipped (with a message) otherwise.
    let dir = artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping real-artifact e2e: run `make artifacts` first");
        return;
    }
    let e = Engine::load(Artifacts::load(dir).expect("artifacts")).expect("engine");
    match decoder::validate_golden(&e) {
        Ok(timing) => assert!(timing.decode_tokens_per_s() > 0.0),
        // Bit-exact reproduction of the JAX golden is only guaranteed
        // under the pjrt backend; the reference executor's integer
        // matmuls are exact but its f32 norm/softmax reductions may
        // differ from XLA's in the last ulp, which can flip a greedy
        // argmax at a near-tie (see rust/README.md). Don't fail the
        // suite for that — surface it.
        Err(err) if e.backend_name() == "reference" => {
            eprintln!(
                "note: reference backend diverged from the JAX golden ({err}); \
                 exactness is guaranteed only under --features pjrt"
            );
        }
        Err(err) => panic!("golden generation on real artifacts: {err:?}"),
    }
}

#[test]
fn kv_cache_threading_matches_monolithic_generation() {
    // Generating [a,b,c,d] in one session must equal feeding the same
    // prefix in a fresh session — cache state is fully captured by the
    // threaded cache values.
    let e = engine();
    let mut full = TinyDecoder::new(&e).unwrap();
    full.generate(&[3, 1, 4, 1], 6).unwrap();

    let mut replay = TinyDecoder::new(&e).unwrap();
    replay.generate(&[3, 1, 4, 1], 0).unwrap();
    // Continue greedily, step by step.
    for _ in 0..6 {
        let next = replay.greedy_next();
        replay.feed(next).unwrap();
    }
    assert_eq!(full.tokens, replay.tokens);
}

#[test]
fn prompts_are_isolated_across_sessions() {
    let e = engine();
    // Interleave two sessions; each must produce what it produces alone.
    let mut alone_a = TinyDecoder::new(&e).unwrap();
    alone_a.generate(&[5, 6], 5).unwrap();
    let mut alone_b = TinyDecoder::new(&e).unwrap();
    alone_b.generate(&[9, 8], 5).unwrap();

    let mut a = TinyDecoder::new(&e).unwrap();
    let mut b = TinyDecoder::new(&e).unwrap();
    a.feed(5).unwrap();
    b.feed(9).unwrap();
    a.feed(6).unwrap();
    b.feed(8).unwrap();
    for _ in 0..5 {
        let na = a.greedy_next();
        a.feed(na).unwrap();
        let nb = b.greedy_next();
        b.feed(nb).unwrap();
    }
    assert_eq!(a.tokens, alone_a.tokens);
    assert_eq!(b.tokens, alone_b.tokens);
}

#[test]
fn serving_end_to_end_with_stats() {
    let e = engine();
    let reqs: Vec<Request> = (0..6)
        .map(|id| Request {
            id,
            prompt: vec![(id % 5) as i32 + 1, 7, 11],
            n_new: 5,
        })
        .collect();
    let t0 = std::time::Instant::now();
    let out = Server::new(&e, Policy::RoundRobin { max_active: 3 })
        .serve(reqs)
        .unwrap();
    let stats = LatencyStats::from_responses(&out, t0.elapsed().as_secs_f64());
    assert_eq!(stats.n, 6);
    assert_eq!(stats.total_tokens, 6 * 8);
    assert!(stats.tokens_per_s > 0.0);
    assert!(stats.p99_service_s >= stats.p50_service_s);
    // Tokens in range.
    for r in &out {
        assert!(r.tokens.iter().all(|&t| t >= 0 && (t as usize) < e.vocab()));
    }
}

#[test]
fn threaded_serving_matches_single_engine() {
    let reqs: Vec<Request> = (0..4)
        .map(|id| Request {
            id,
            prompt: vec![(id % 3) as i32 + 1, 2],
            n_new: 4,
        })
        .collect();
    let single = Server::new(&engine(), Policy::RoundRobin { max_active: 2 })
        .serve(reqs.clone())
        .unwrap();
    let threaded = serve_threaded_with(
        || Engine::load(Artifacts::synthetic(SEED)?),
        reqs,
        2,
        2,
    )
    .unwrap();
    assert_eq!(threaded.len(), 4);
    for t in &threaded {
        let s = single.iter().find(|s| s.id == t.id).unwrap();
        assert_eq!(s.tokens, t.tokens, "request {}", t.id);
    }
}

#[test]
fn logits_are_stable_across_engine_instances() {
    // Two engines built from the same artifacts must agree bitwise.
    let e1 = engine();
    let e2 = engine();
    let s1 = e1.new_session().unwrap();
    let s2 = e2.new_session().unwrap();
    let o1 = e1.decode_step(s1, 42, 0).unwrap();
    let o2 = e2.decode_step(s2, 42, 0).unwrap();
    assert_eq!(o1, o2);
}

#[test]
fn missing_parameter_fails_at_load_not_mid_decode() {
    // Failure injection: a manifest missing a required parameter must be
    // rejected when the engine is built, not during token generation.
    let mut a = Artifacts::synthetic(SEED).unwrap();
    let idx = a
        .manifest
        .params
        .iter()
        .position(|p| p.name == "layer0.w_out")
        .unwrap();
    a.manifest.params[idx].name = "layer0.w_out_renamed".to_string();
    assert!(Engine::load(a).is_err());
}

#[test]
fn out_of_range_token_still_safe() {
    // Token ids index the embedding via gather; out-of-range ids must
    // not crash the engine (XLA clamps gather indices; the reference
    // backend mirrors that).
    let e = engine();
    let s = e.new_session().unwrap();
    let out = e.decode_step(s, (e.vocab() as i32) + 500, 0);
    if let Ok(logits) = out {
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
