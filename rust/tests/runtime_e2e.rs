//! End-to-end runtime tests: AOT artifacts -> PJRT -> token generation
//! -> serving, the full functional path of the system. Skipped (with a
//! message) when `make artifacts` has not been run.

use pim_llm::runtime::{artifacts, decoder, Artifacts, Engine, TinyDecoder};
use pim_llm::serving::{LatencyStats, Policy, Request, Server};

fn engine() -> Option<Engine> {
    let dir = artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime e2e: run `make artifacts` first");
        return None;
    }
    Some(Engine::load(Artifacts::load(dir).expect("artifacts")).expect("engine"))
}

#[test]
fn golden_generation_token_for_token() {
    let Some(e) = engine() else { return };
    decoder::validate_golden(&e).expect("rust+PJRT must reproduce the jax golden generation");
}

#[test]
fn kv_cache_threading_matches_monolithic_generation() {
    // Generating [a,b,c,d] in one session must equal feeding the same
    // prefix in a fresh session — cache state is fully captured by the
    // returned literals.
    let Some(e) = engine() else { return };
    let mut full = TinyDecoder::new(&e).unwrap();
    full.generate(&[3, 1, 4, 1], 6).unwrap();

    let mut replay = TinyDecoder::new(&e).unwrap();
    replay.generate(&[3, 1, 4, 1], 0).unwrap();
    // Continue greedily, step by step.
    for _ in 0..6 {
        let next = replay.greedy_next();
        replay.feed(next).unwrap();
    }
    assert_eq!(full.tokens, replay.tokens);
}

#[test]
fn prompts_are_isolated_across_sessions() {
    let Some(e) = engine() else { return };
    // Interleave two sessions; each must produce what it produces alone.
    let mut alone_a = TinyDecoder::new(&e).unwrap();
    alone_a.generate(&[5, 6], 5).unwrap();
    let mut alone_b = TinyDecoder::new(&e).unwrap();
    alone_b.generate(&[9, 8], 5).unwrap();

    let mut a = TinyDecoder::new(&e).unwrap();
    let mut b = TinyDecoder::new(&e).unwrap();
    a.feed(5).unwrap();
    b.feed(9).unwrap();
    a.feed(6).unwrap();
    b.feed(8).unwrap();
    for _ in 0..5 {
        let na = a.greedy_next();
        a.feed(na).unwrap();
        let nb = b.greedy_next();
        b.feed(nb).unwrap();
    }
    assert_eq!(a.tokens, alone_a.tokens);
    assert_eq!(b.tokens, alone_b.tokens);
}

#[test]
fn serving_end_to_end_with_stats() {
    let Some(e) = engine() else { return };
    let reqs: Vec<Request> = (0..6)
        .map(|id| Request {
            id,
            prompt: vec![(id % 5) as i32 + 1, 7, 11],
            n_new: 5,
        })
        .collect();
    let t0 = std::time::Instant::now();
    let out = Server::new(&e, Policy::RoundRobin { max_active: 3 })
        .serve(reqs)
        .unwrap();
    let stats = LatencyStats::from_responses(&out, t0.elapsed().as_secs_f64());
    assert_eq!(stats.n, 6);
    assert_eq!(stats.total_tokens, 6 * 8);
    assert!(stats.tokens_per_s > 0.0);
    assert!(stats.p99_service_s >= stats.p50_service_s);
    // Tokens in range.
    for r in &out {
        assert!(r.tokens.iter().all(|&t| t >= 0 && (t as usize) < e.vocab()));
    }
}

#[test]
fn logits_are_stable_across_engine_instances() {
    // Two engines compiled from the same artifacts must agree bitwise.
    let Some(e1) = engine() else { return };
    let e2 = Engine::load(Artifacts::load(artifacts::default_dir()).unwrap()).unwrap();
    let o1 = e1.decode_step(e1.empty_caches().unwrap(), 42, 0).unwrap();
    let o2 = e2.decode_step(e2.empty_caches().unwrap(), 42, 0).unwrap();
    assert_eq!(o1.logits, o2.logits);
}

#[test]
fn corrupt_hlo_rejected_at_load() {
    // Failure injection: valid manifest/weights/golden but truncated HLO
    // text must fail at Engine::load (the PJRT parse step), not later.
    let dir = artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let tmp = std::env::temp_dir().join(format!("pimllm-hlo-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    for f in ["manifest.json", "golden.json", "weights.bin"] {
        std::fs::copy(dir.join(f), tmp.join(f)).unwrap();
    }
    let hlo = std::fs::read_to_string(dir.join("decode_step.hlo.txt")).unwrap();
    std::fs::write(tmp.join("decode_step.hlo.txt"), &hlo[..hlo.len() / 3]).unwrap();
    let arts = Artifacts::load(&tmp).expect("artifacts themselves are valid");
    let result = Engine::load(arts);
    std::fs::remove_dir_all(&tmp).ok();
    assert!(result.is_err(), "truncated HLO must not compile");
}

#[test]
fn out_of_range_token_still_safe() {
    // Token ids index the embedding via gather; out-of-range ids must
    // not crash the engine (XLA clamps gather indices).
    let Some(e) = engine() else { return };
    let out = e.decode_step(e.empty_caches().unwrap(), (e.vocab() as i32) + 500, 0);
    if let Ok(o) = out {
        assert!(o.logits.iter().all(|x| x.is_finite()));
    }
}
