//! Differential harness for the int8 KV arena (`--kv-quant int8`):
//! the lossy layout must TRACK the f32 oracle within quantization
//! error, and everything that should stay exact must stay exact:
//!
//! * Reference and packed backends read the same int8 blocks through
//!   the same `attention_paged_q8` kernel, so their logits are
//!   BIT-FOR-BIT identical in int8 mode — quantization is lossy
//!   against f32, never nondeterministic.
//! * Re-prefilling the same tokens reproduces the same codes and group
//!   scales (requantize-on-grow is a function of the row sequence), so
//!   evict -> re-admit cycles and scheduler choice cannot change
//!   outputs.
//! * Prefix adoption of FULL blocks shares the donor's codes + scales
//!   verbatim, and a full block's scale is determined by its own rows —
//!   bitwise equal to cold int8 prefill. Partial-tail COW inherits the
//!   donor's (possibly coarser) group scale, so it only tracks cold
//!   prefill within quantization error — asserted as such.
//!
//! Tolerances: the kernel-level bound (`kernels::tests`) shows the q8
//! attention output within ~2 quantization steps of the W8A8 oracle
//! (empirically ~0.7% of the value scale). RMSNorm between layers keeps
//! relative error roughly flat, so end-to-end logits stay within a few
//! percent of the f32 path; 0.35 of the per-step max-|logit| is a wide
//! margin for that drift while still failing hard on real defects
//! (stale group scales, swapped heads, mis-indexed blocks all produce
//! O(100%) divergence).

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{ArenaLayout, Artifacts, BackendKind, Engine};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::rng::Rng;

const HOST_BACKENDS: [BackendKind; 2] = [BackendKind::Reference, BackendKind::Packed];

/// Small-but-varied random model shapes (block boundaries land
/// mid-head, like the paged/prefix equivalence suites).
fn random_model(rng: &mut Rng) -> ModelInfo {
    let h = [1usize, 2, 4][rng.range(0, 2)];
    ModelInfo {
        vocab: rng.range(8, 60),
        d: h * [3usize, 5, 8][rng.range(0, 2)],
        h,
        d_ff: rng.range(9, 40),
        n_layers: rng.range(1, 2),
        max_ctx: rng.range(12, 24),
        eps: 1e-5,
    }
}

/// Teacher-forced run: decode `tokens` through a fresh session and
/// return the per-step logits plus the final gathered caches.
fn forced_run(engine: &Engine, tokens: &[i32]) -> (Vec<Vec<f32>>, (Vec<f32>, Vec<f32>)) {
    let s = engine.new_session().unwrap();
    let logits: Vec<Vec<f32>> = tokens
        .iter()
        .enumerate()
        .map(|(pos, &t)| engine.decode_step(s, t, pos as i32).unwrap())
        .collect();
    let caches = engine.gather_session(s).unwrap();
    engine.free_session(s).unwrap();
    (logits, caches)
}

/// Every element finite and within `rel * max|want|` of the oracle.
fn assert_tracks(got: &[f32], want: &[f32], rel: f32, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    let scale = want.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-3);
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(g.is_finite(), "{label}: non-finite logit at {i}");
        assert!(
            (g - w).abs() <= rel * scale,
            "{label}: |{g} - {w}| > {rel} * {scale} at {i}"
        );
    }
}

#[test]
fn int8_decode_tracks_the_f32_oracle_and_is_bitwise_across_backends() {
    // Random models x block lens: teacher-force one token stream
    // through an f32 engine and int8 engines on both host backends.
    // int8 vs f32 is bounded-divergence; int8 vs int8 across backends
    // is assert_eq — the projections are bit-identical (PR 2) and both
    // read the arena through the same q8 kernel.
    for seed in 0..4u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0x9D2C_5681).wrapping_add(23));
        let model = random_model(&mut rng);
        let tokens: Vec<i32> = (0..model.max_ctx - 1)
            .map(|_| rng.range(0, model.vocab - 1) as i32)
            .collect();
        for block_len in [1usize, 3, 0] {
            let artifacts = || Artifacts::synthetic_with(seed, model.clone()).unwrap();
            let oracle =
                Engine::load_with_arena(artifacts(), BackendKind::Reference, block_len, 64)
                    .unwrap();
            let (want, _) = forced_run(&oracle, &tokens);

            let mut per_backend: Vec<(Vec<Vec<f32>>, (Vec<f32>, Vec<f32>))> = Vec::new();
            for kind in HOST_BACKENDS {
                let q8 = Engine::load_with_arena_mode(
                    artifacts(),
                    kind,
                    block_len,
                    64,
                    ArenaLayout::KvInt8,
                )
                .unwrap();
                assert_eq!(q8.arena_mode(), ArenaLayout::KvInt8);
                let (got, caches) = forced_run(&q8, &tokens);
                for (pos, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_tracks(
                        g,
                        w,
                        0.35,
                        &format!("seed {seed} {kind:?} bl {block_len} pos {pos}"),
                    );
                }
                q8.debug_validate().unwrap();
                per_backend.push((got, caches));
            }
            let (ref_logits, ref_caches) = &per_backend[0];
            let (pk_logits, pk_caches) = &per_backend[1];
            assert_eq!(
                ref_logits, pk_logits,
                "seed {seed} bl {block_len}: int8 logits must be bitwise \
                 identical across host backends"
            );
            assert_eq!(
                ref_caches, pk_caches,
                "seed {seed} bl {block_len}: int8 gathered caches must be \
                 bitwise identical across host backends"
            );
        }
    }
}

#[test]
fn int8_reprefill_is_bitwise_reproducible() {
    // Quantization state is a pure function of the row sequence: a
    // second session fed the same tokens (after the first is evicted,
    // so it even reuses the same physical blocks) reproduces logits
    // and gathered caches exactly. This is what makes continuous
    // batching's preempt -> re-prefill cycle safe in int8 mode.
    for kind in HOST_BACKENDS {
        let engine = Engine::load_with_arena_mode(
            Artifacts::synthetic(0xEB8).unwrap(),
            kind,
            4,
            8,
            ArenaLayout::KvInt8,
        )
        .unwrap();
        let tokens: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3];
        let (a_logits, a_caches) = forced_run(&engine, &tokens);
        let (b_logits, b_caches) = forced_run(&engine, &tokens);
        assert_eq!(a_logits, b_logits, "{kind:?}: re-prefill logits");
        assert_eq!(a_caches, b_caches, "{kind:?}: re-prefill caches");
        engine.debug_validate().unwrap();
    }
}

#[test]
fn int8_full_block_adoption_is_bitwise_and_partial_tail_is_bounded() {
    for kind in HOST_BACKENDS {
        let artifacts = || Artifacts::synthetic(0x8BAD).unwrap();
        let warm =
            Engine::load_with_arena_mode(artifacts(), kind, 4, 32, ArenaLayout::KvInt8)
                .unwrap();
        assert!(warm.enable_prefix_cache(0));
        let cold =
            Engine::load_with_arena_mode(artifacts(), kind, 4, 32, ArenaLayout::KvInt8)
                .unwrap();

        // Donor: 12 tokens = 3 full blocks indexed.
        let donor: Vec<i32> = vec![5, 1, 8, 2, 9, 9, 4, 7, 3, 6, 1, 2];
        let ds = warm.new_session().unwrap();
        for (pos, &t) in donor.iter().enumerate() {
            warm.decode_step(ds, t, pos as i32).unwrap();
        }
        warm.prefix_insert(ds, &donor).unwrap();
        let donor_caches = warm.gather_session(ds).unwrap();

        // Full-block adoption (9 usable -> 8 = 2 whole blocks, shared
        // read-only): a full block's group scales are fixed by its own
        // rows, which cold prefill writes identically — bitwise equal.
        let prompt = donor[..9].to_vec();
        let (want_logits, want_caches) = forced_run(&cold, &prompt);
        let s = warm.new_session().unwrap();
        let skipped = warm.prefix_adopt(s, &prompt).unwrap();
        assert_eq!(skipped, 8, "{kind:?}: expected 2 full shared blocks");
        for (pos, &t) in prompt.iter().enumerate().skip(skipped) {
            assert_eq!(
                warm.decode_step(s, t, pos as i32).unwrap(),
                want_logits[pos],
                "{kind:?}: full-block adoption must be bitwise cold at {pos}"
            );
        }
        assert_eq!(warm.gather_session(s).unwrap(), want_caches, "{kind:?}");
        warm.free_session(s).unwrap();

        // Partial-tail adoption (11 -> 10 = 2 blocks + 2 COW rows): the
        // copied tail keeps the donor's group scale, whose absmax may
        // reflect rows beyond the kept ones — a COARSER grid than cold
        // prefill of just those rows would use. So: bounded, not
        // bitwise, and the donor must stay untouched.
        let prompt = donor[..11].to_vec();
        let (want_logits, want_caches) = forced_run(&cold, &prompt);
        let s = warm.new_session().unwrap();
        let skipped = warm.prefix_adopt(s, &prompt).unwrap();
        assert_eq!(skipped, 10, "{kind:?}: 2 full blocks + 2 tail rows");
        for (pos, &t) in prompt.iter().enumerate().skip(skipped) {
            let got = warm.decode_step(s, t, pos as i32).unwrap();
            assert_tracks(&got, &want_logits[pos], 0.35, &format!("{kind:?} pos {pos}"));
        }
        let (gk, gv) = warm.gather_session(s).unwrap();
        assert_tracks(&gk, &want_caches.0, 0.35, &format!("{kind:?} tail K"));
        assert_tracks(&gv, &want_caches.1, 0.35, &format!("{kind:?} tail V"));
        assert_eq!(
            warm.gather_session(ds).unwrap(),
            donor_caches,
            "{kind:?}: adopter COW must not disturb the donor"
        );
        warm.free_session(s).unwrap();
        warm.free_session(ds).unwrap();
        warm.debug_validate().unwrap();
    }
}

#[test]
fn int8_serving_is_scheduler_independent_and_survives_tight_arenas() {
    // With the prefix cache off, a session's int8 state depends only on
    // its own (token, position) sequence — blocks are zeroed on claim
    // and re-prefill is bitwise — so FIFO, batched, and continuous
    // scheduling must all emit identical tokens, even when a tight
    // arena forces continuous batching to preempt and re-prefill.
    let mut rng = Rng::new(0x8EED);
    let requests: Vec<Request> = (0..8u64)
        .map(|id| {
            let prompt: Vec<i32> = (0..rng.range(3, 8)).map(|_| rng.range(1, 60) as i32).collect();
            Request { id, prompt, n_new: rng.range(2, 5) }
        })
        .collect();
    for kind in HOST_BACKENDS {
        let engine_with = |capacity_blocks: usize| {
            Engine::load_with_arena_mode(
                Artifacts::synthetic(0x8EED).unwrap(),
                kind,
                3,
                capacity_blocks,
                ArenaLayout::KvInt8,
            )
            .unwrap()
        };
        let roomy = engine_with(64);
        let baseline = Server::new(&roomy, Policy::Fifo).serve(requests.clone()).unwrap();
        for (policy, capacity) in [
            (Policy::Batched { batch: 4 }, 64usize),
            (Policy::Continuous { max_active: 4 }, 64),
            // Tight: ~2 worst-case sessions of blocks for 4 active.
            (Policy::Continuous { max_active: 4 }, 12),
        ] {
            let e = engine_with(capacity);
            let out = Server::new(&e, policy).serve(requests.clone()).unwrap();
            for b in &baseline {
                let r = out.iter().find(|r| r.id == b.id).unwrap();
                assert_eq!(
                    b.tokens, r.tokens,
                    "{kind:?} {policy:?} cap {capacity} request {}",
                    b.id
                );
            }
            e.debug_validate().unwrap();
            let st = e.arena_status();
            assert_eq!(st.used_bytes, st.used_blocks * st.block_bytes);
        }

        // Prefix cache ON still serves correctly (tokens may differ
        // from cache-off where partial-tail COW coarsens a grid, so
        // assert the cache WORKS, not bitwise equality): shared system
        // prompts must hit, and two identical cached runs must agree
        // with each other.
        let system: Vec<i32> = (0..7).map(|_| rng.range(1, 60) as i32).collect();
        let shared: Vec<Request> = (0..6u64)
            .map(|id| {
                let mut prompt = system.clone();
                prompt.push(id as i32 + 1);
                Request { id, prompt, n_new: 3 }
            })
            .collect();
        let cached_run = || {
            let e = engine_with(64);
            assert!(e.enable_prefix_cache(0));
            let out = Server::new(&e, Policy::Continuous { max_active: 3 })
                .serve(shared.clone())
                .unwrap();
            let stats = e.prefix_stats().unwrap();
            assert!(stats.saved_tokens > 0, "{kind:?}: shared prefixes must hit");
            e.debug_validate().unwrap();
            out
        };
        let (a, b) = (cached_run(), cached_run());
        for ra in &a {
            let rb = b.iter().find(|r| r.id == ra.id).unwrap();
            assert_eq!(ra.tokens, rb.tokens, "{kind:?}: cached serving must be deterministic");
        }
    }
}
