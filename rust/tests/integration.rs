//! Cross-module integration tests: config -> workload -> schedulers ->
//! analysis, exercising whole figure pipelines and the CLI-facing
//! generators against the paper's stated numbers.

use pim_llm::analysis::figures;
use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, token_loop, Arch};
use pim_llm::models::{self, CONTEXT_LENGTHS};
use pim_llm::util::toml;

#[test]
fn full_fig5_pipeline_hits_all_paper_points() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::fig5(&arch);
    assert_eq!(rows.len(), 7 * CONTEXT_LENGTHS.len());
    let stated: Vec<_> = rows.iter().filter(|r| r.paper_speedup.is_some()).collect();
    assert_eq!(stated.len(), 4, "four annotated points in §IV-A");
    for r in stated {
        let ps = r.paper_speedup.unwrap();
        assert!(
            (r.speedup - ps).abs() / ps < 0.15,
            "{} l={}: {:.2} vs {:.2}",
            r.model,
            r.context,
            r.speedup,
            ps
        );
    }
}

#[test]
fn fig6_reference_percentages_reproduced() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::fig6(&arch);
    let pct = |model: &str, l: usize, comp: &str| {
        rows.iter()
            .find(|r| r.model == model && r.context == l)
            .unwrap()
            .percents
            .iter()
            .find(|(k, _)| k == comp)
            .unwrap()
            .1
    };
    // §IV-B statements with generous tolerances (we reproduce shape).
    assert!((pct("OPT-6.7B", 128, "systolic") - 60.0).abs() < 10.0);
    assert!((pct("GPT2-355M", 128, "systolic") - 73.9).abs() < 10.0);
    assert!((pct("OPT-6.7B", 128, "communication") - 36.3).abs() < 10.0);
    assert!((pct("GPT2-355M", 128, "communication") - 10.7).abs() < 6.0);
    assert!((pct("GPT2-355M", 128, "buffer") - 14.7).abs() < 6.0);
    assert!((pct("OPT-6.7B", 128, "buffer") - 3.5) < 3.0);
    assert!(pct("OPT-6.7B", 4096, "systolic") > 90.0);
    assert!(pct("GPT2-355M", 4096, "systolic") > 90.0);
    // Analog PIM path below 1% everywhere (paper: "remain below 1%").
    for r in &rows {
        let analog: f64 = r
            .percents
            .iter()
            .filter(|(k, _)| k == "xbar" || k == "dac" || k == "adc")
            .map(|(_, v)| v)
            .sum();
        assert!(analog < 1.0, "{} l={}: {analog}", r.model, r.context);
    }
}

#[test]
fn fig7_crossover_and_fig8_transform() {
    let arch = ArchConfig::paper_45nm();
    let f7 = figures::fig7(&arch);
    // TPU-LLM wins the smallest model at short context...
    let g = |m: &str, l: usize| {
        f7.iter()
            .find(|r| r.model == m && r.context == l)
            .unwrap()
            .gain_pct
    };
    assert!(g("GPT2-355M", 128) < 0.0);
    // ...and the gain is monotone in model size along the OPT family.
    assert!(g("OPT-1.3B", 128) < g("OPT-2.7B", 128));
    assert!(g("OPT-2.7B", 128) < g("OPT-6.7B", 128));
    assert!(g("OPT-6.7B", 128) > 0.0);

    // Fig. 8 is an exact transform of Fig. 7.
    let f8 = figures::fig8(&arch);
    for (r7, r8) in f7.iter().zip(f8.iter()) {
        let want = pim_llm::energy::BATTERY_JOULES * r7.pim_llm_tokens_per_j
            / pim_llm::energy::TOKENS_PER_WORD;
        assert!((r8.pim_llm_words - want).abs() / want < 1e-9);
    }
}

#[test]
fn table3_beats_prior_work_as_stated() {
    let arch = ArchConfig::paper_45nm();
    let rows = figures::table3(&arch);
    let ours = |m: &str, l: usize| {
        rows.iter()
            .find(|r| r.design.contains("ours") && r.model == m && r.context == l)
            .unwrap()
    };
    // "2x improvement in GOPS" vs HARDSEA (3.2 GOPS).
    assert!(ours("GPT2-Small", 1024).gops.unwrap() > 1.6 * 3.2);
    // "5x improvement in GOPS/W" vs TransPIM (< 200 GOPS/W).
    assert!(ours("GPT2-Medium", 4096).gops_per_w.unwrap() > 2.0 * 200.0);
    // Paper's four stated PIM-LLM GOPS values within 25%.
    for (m, l) in [
        ("GPT2-Small", 1024usize),
        ("GPT2-Medium", 4096),
        ("OPT-6.7B", 1024),
        ("OPT-6.7B", 4096),
    ] {
        let r = ours(m, l);
        let rel = (r.gops.unwrap() - r.paper_gops.unwrap()).abs() / r.paper_gops.unwrap();
        assert!(rel < 0.25, "{m} l={l}: {:?} vs {:?}", r.gops, r.paper_gops);
    }
}

#[test]
fn calibrated_config_roundtrips_through_cli_path() {
    // What `repro --config` does: serialize -> reparse -> identical sim.
    let arch = ArchConfig::paper_45nm();
    let text = arch.to_toml_string();
    let doc = toml::parse(&text).unwrap();
    assert!(doc.table("tpu").is_ok() && doc.table("pim").is_ok());
    let back = ArchConfig::from_toml_str(&text).unwrap();
    let m = models::by_name("OPT-1.3B").unwrap();
    let a = coordinator::simulate(&arch, &m, 512, Arch::PimLlm);
    let b = coordinator::simulate(&back, &m, 512, Arch::PimLlm);
    assert_eq!(a, b);
}

#[test]
fn generation_accounting_consistent_with_step_sim() {
    let arch = ArchConfig::paper_45nm();
    let m = models::by_name("GPT2-355M").unwrap();
    let g = token_loop::generate(&arch, &m, Arch::PimLlm, 4, 8);
    // Sum of independently simulated steps == generation total.
    let mut want = 0.0;
    for p in 0..12 {
        want += coordinator::simulate(&arch, &m, p + 1, Arch::PimLlm).latency_s();
    }
    assert!((g.total_latency_s - want).abs() < 1e-12);
}

#[test]
fn every_table2_model_simulates_at_every_context() {
    let arch = ArchConfig::paper_45nm();
    for m in models::table2_models() {
        for l in CONTEXT_LENGTHS {
            for a in [Arch::PimLlm, Arch::TpuLlm] {
                let r = coordinator::simulate(&arch, &m, l, a);
                assert!(r.latency_s() > 0.0, "{} l={l} {a:?}", m.name);
            }
        }
    }
}
