//! Property tests for the batched decode path: batching is a throughput
//! optimization, NEVER a numerics change. For random synthetic models
//! and random ragged prompt/generation mixes,
//!
//! * `BatchDecoder` (one `decode_batch` per step for all lanes) must be
//!   token-for-token AND logit-for-logit identical to one `TinyDecoder`
//!   per lane (one `decode_step` per token), and
//! * `Server::serve` must produce identical tokens under `Fifo`,
//!   `RoundRobin`, and the batched scheduler.
//!
//! The offline build has no proptest; randomness comes from the
//! in-crate SplitMix64 (`util::rng`) with fixed seeds, so every failure
//! is reproducible.

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BatchDecoder, Engine, TinyDecoder};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::rng::Rng;

/// Random ragged workload: `lanes` prompts of length 0..=4 with 0..=5
/// new tokens each — deliberately including empty prompts and
/// zero-generation lanes.
fn ragged_mix(rng: &mut Rng, vocab: usize, lanes: usize) -> (Vec<Vec<i32>>, Vec<usize>) {
    let mut prompts = Vec::with_capacity(lanes);
    let mut n_new = Vec::with_capacity(lanes);
    for _ in 0..lanes {
        let p_len = rng.range(0, 4);
        prompts.push(
            (0..p_len)
                .map(|_| rng.range(0, vocab - 1) as i32)
                .collect(),
        );
        n_new.push(rng.range(0, 5));
    }
    (prompts, n_new)
}

#[test]
fn batch_decoder_equals_tiny_decoder_over_random_models_and_mixes() {
    for seed in [1u64, 7, 42] {
        let engine = Engine::load(Artifacts::synthetic(seed).unwrap()).unwrap();
        let vocab = engine.vocab();
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9));
        for case in 0..3 {
            let lanes = rng.range(1, 6);
            let (prompts, n_new) = ragged_mix(&mut rng, vocab, lanes);
            let mut batch = BatchDecoder::new(&engine);
            batch.generate(&prompts, &n_new).unwrap();
            for (i, (p, &n)) in prompts.iter().zip(&n_new).enumerate() {
                let mut tiny = TinyDecoder::new(&engine).unwrap();
                tiny.generate(p, n).unwrap();
                assert_eq!(
                    batch.session(i).tokens,
                    tiny.tokens,
                    "seed {seed} case {case} lane {i}: tokens diverged"
                );
                assert_eq!(
                    batch.session(i).last_logits,
                    tiny.last_logits,
                    "seed {seed} case {case} lane {i}: logits diverged"
                );
            }
        }
    }
}

#[test]
fn batched_kernel_column_striping_is_bitwise_equal_on_a_sized_model() {
    // Large enough that `bitlinear_batch` crosses its parallel-stripe
    // threshold at batch 8 (8 * 256 * 1024 MACs on the FF matrices), so
    // this exercises the threaded weight walk — which must still be
    // bit-identical to the serial per-session path.
    let model = ModelInfo {
        vocab: 64,
        d: 256,
        h: 4,
        d_ff: 1024,
        n_layers: 1,
        max_ctx: 16,
        eps: 1e-5,
    };
    let engine = Engine::load(Artifacts::synthetic_with(5, model).unwrap()).unwrap();
    let prompts: Vec<Vec<i32>> = (0..8).map(|i| vec![i + 1, (i * 3) % 60]).collect();
    let n_new = vec![2usize; 8];
    let mut batch = BatchDecoder::new(&engine);
    batch.generate(&prompts, &n_new).unwrap();
    for (i, p) in prompts.iter().enumerate() {
        let mut tiny = TinyDecoder::new(&engine).unwrap();
        tiny.generate(p, 2).unwrap();
        assert_eq!(batch.session(i).tokens, tiny.tokens, "lane {i}");
        assert_eq!(batch.session(i).last_logits, tiny.last_logits, "lane {i}");
    }
}

#[test]
fn server_tokens_identical_across_all_schedulers() {
    for seed in [3u64, 19] {
        let engine = Engine::load(Artifacts::synthetic(seed).unwrap()).unwrap();
        let vocab = engine.vocab();
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        let requests: Vec<Request> = (0..8u64)
            .map(|id| {
                let p_len = rng.range(0, 5);
                Request {
                    id,
                    prompt: (0..p_len)
                        .map(|_| rng.range(0, vocab - 1) as i32)
                        .collect(),
                    n_new: rng.range(0, 6),
                }
            })
            .collect();
        let reference = Server::new(&engine, Policy::Fifo)
            .serve(requests.clone())
            .unwrap();
        for policy in [
            Policy::RoundRobin { max_active: 3 },
            Policy::Batched { batch: 3 },
            Policy::Batched { batch: 8 },
            Policy::Continuous { max_active: 3 },
            Policy::Continuous { max_active: 8 },
        ] {
            let out = Server::new(&engine, policy).serve(requests.clone()).unwrap();
            assert_eq!(out.len(), reference.len(), "seed {seed} {policy:?}");
            for r in &reference {
                let o = out.iter().find(|o| o.id == r.id).unwrap();
                assert_eq!(
                    r.tokens, o.tokens,
                    "seed {seed} request {} under {policy:?}",
                    r.id
                );
            }
        }
    }
}

#[test]
fn prompt_and_generate_lanes_mix_within_one_tick() {
    // A long-prompt request admitted next to an already-generating one
    // forces ticks where one lane is prefilling while the other decodes;
    // both must still match their solo runs exactly.
    let engine = Engine::load(Artifacts::synthetic(23).unwrap()).unwrap();
    let requests = vec![
        Request { id: 0, prompt: vec![1], n_new: 9 },
        Request { id: 1, prompt: vec![2, 3, 4, 5, 6, 7, 8], n_new: 3 },
    ];
    let batched = Server::new(&engine, Policy::Batched { batch: 2 })
        .serve(requests.clone())
        .unwrap();
    for req in requests {
        let solo = Server::new(&engine, Policy::Fifo)
            .serve(vec![req.clone()])
            .unwrap();
        let b = batched.iter().find(|r| r.id == req.id).unwrap();
        assert_eq!(solo[0].tokens, b.tokens, "request {}", req.id);
    }
}
