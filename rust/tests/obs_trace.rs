//! Integration suite for the observability layer: ring-buffer
//! wraparound and drop accounting, concurrent drain-while-recording,
//! the disabled-is-inert guarantee, and the full serve → drain →
//! Chrome-trace JSON → in-crate parser → Perfetto-schema checker
//! round trip on both the single-engine and sharded serving paths.
//! (The zero-allocation guarantees live in unit tests in `src/` —
//! the counting allocator is only registered under `cfg(test)` of the
//! library crate, so integration tests cannot observe it.)

use std::sync::Arc;
use std::thread;

use pim_llm::obs::export::{check_trace_doc, chrome_trace};
use pim_llm::obs::{Counter, Event, EventKind, SpanKind, TraceSink};
use pim_llm::runtime::{Artifacts, BackendKind, Engine, ShardedEngine};
use pim_llm::serving::{serve_sharded_stats_opts, Policy, Request, Server};
use pim_llm::util::json;

const SEED: u64 = 0x0B5;

fn requests(n: u64) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id,
            prompt: (0..(id % 4) as i32 + 1).map(|i| (id as i32 * 7 + i) % 60 + 1).collect(),
            n_new: (id % 3) as usize + 2,
        })
        .collect()
}

#[test]
fn wraparound_keeps_newest_events_and_counts_every_drop() {
    let sink = TraceSink::with_capacity(16);
    sink.set_enabled(true);
    for i in 0..50u64 {
        sink.record(EventKind::Admit, SpanKind::None, i, 0);
    }
    assert_eq!(sink.len(), 16);
    assert_eq!(sink.dropped(), 34);
    let events = sink.drain();
    assert_eq!(events.len(), 16);
    // Chronological drain: exactly the newest 16, oldest-first, with
    // non-decreasing timestamps.
    for (j, ev) in events.iter().enumerate() {
        assert_eq!(ev.a, 34 + j as u64, "slot {j} holds the wrong event");
    }
    for w in events.windows(2) {
        assert!(w[0].t_ns <= w[1].t_ns, "timestamps went backwards");
    }
    // The drop counter is cumulative: a fresh burst after the drain
    // keeps counting from 34, not from zero.
    for i in 0..20u64 {
        sink.record(EventKind::Admit, SpanKind::None, 100 + i, 0);
    }
    assert_eq!(sink.dropped(), 38);
    assert_eq!(sink.drain().len(), 16);
}

#[test]
fn drain_while_recording_from_another_thread_accounts_for_every_event() {
    const TOTAL: u64 = 10_000;
    let sink = Arc::new(TraceSink::with_capacity(256));
    sink.set_enabled(true);
    let recorder = {
        let sink = Arc::clone(&sink);
        thread::spawn(move || {
            for i in 0..TOTAL {
                sink.record(EventKind::TickStart, SpanKind::None, i, 0);
            }
        })
    };
    let mut drained: Vec<Event> = Vec::new();
    for _ in 0..64 {
        drained.extend(sink.drain());
        thread::yield_now();
    }
    recorder.join().unwrap();
    drained.extend(sink.drain());
    // Exactly-once: every recorded event either reached a drain or was
    // counted as dropped by an overwrite — no loss, no duplication.
    assert_eq!(drained.len() as u64 + sink.dropped(), TOTAL);
    // Concatenated drains replay record order: payloads strictly
    // increase (gaps are the dropped events) and time never reverses.
    for w in drained.windows(2) {
        assert!(w[0].a < w[1].a, "drain order broke record order");
        assert!(w[0].t_ns <= w[1].t_ns, "timestamps went backwards");
    }
}

#[test]
fn disabled_sink_and_disabled_serve_emit_zero_events() {
    // A never-enabled sink records nothing and counts nothing dropped.
    let sink = TraceSink::with_capacity(64);
    for i in 0..100u64 {
        sink.record(EventKind::Retire, SpanKind::None, i, 0);
    }
    assert!(sink.drain().is_empty());
    assert_eq!(sink.dropped(), 0);

    // End to end: serving with observability left at its default (off)
    // leaves both the ring and every metric untouched.
    let engine = Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap();
    let out = Server::new(&engine, Policy::Continuous { max_active: 3 })
        .serve(requests(8))
        .unwrap();
    assert_eq!(out.len(), 8);
    assert!(engine.obs().trace.drain().is_empty());
    assert_eq!(engine.obs().trace.dropped(), 0);
    let snap = engine.metrics_snapshot();
    assert_eq!(snap.counter(Counter::TicksRun), 0);
    assert_eq!(snap.counter(Counter::TokensDecoded), 0);
    assert_eq!(snap.counter(Counter::Admitted), 0);
}

#[test]
fn single_engine_trace_round_trips_through_the_perfetto_checker() {
    let engine = Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap();
    engine.obs().set_enabled(true);
    let out = Server::new(&engine, Policy::Continuous { max_active: 3 })
        .serve(requests(8))
        .unwrap();
    assert_eq!(out.len(), 8);
    let events = engine.obs().trace.drain();
    assert!(!events.is_empty(), "traced serve produced no events");
    // Ticks, admissions, and retirements must all appear in the ring.
    for kind in [EventKind::TickStart, EventKind::TickEnd, EventKind::Admit, EventKind::Retire] {
        assert!(
            events.iter().any(|e| e.kind == kind),
            "no {kind:?} event in trace"
        );
    }
    // Request phases land as span begin/end pairs.
    assert!(events
        .iter()
        .any(|e| e.kind == EventKind::SpanBegin && e.span == SpanKind::Decode));
    let tracks = vec![(engine.obs().shard(), events)];
    let text = chrome_trace(&tracks).to_string();
    let doc = json::parse(&text).expect("exported trace must parse with util::json");
    let (n_events, n_tracks) = check_trace_doc(&doc).expect("Perfetto schema check");
    assert!(n_events > 0);
    assert_eq!(n_tracks, 1);
    // Metrics agree with the served workload.
    let snap = engine.metrics_snapshot();
    assert!(snap.counter(Counter::TicksRun) > 0);
    assert!(snap.counter(Counter::TokensDecoded) > 0);
    assert_eq!(snap.counter(Counter::Admitted), 8);
    assert_eq!(snap.counter(Counter::Retired), 8);
}

#[test]
fn sharded_drain_produces_one_monotonic_track_per_worker() {
    let n = 12u64;
    let mut engine = ShardedEngine::load(
        Artifacts::synthetic(SEED).unwrap(),
        BackendKind::Reference,
        4,
        64,
        4,
    )
    .unwrap();
    engine.set_obs_enabled(true);
    let offsets = vec![0.0; n as usize];
    let (out, stats) =
        serve_sharded_stats_opts(&mut engine, requests(n), &offsets, 2, 3).unwrap();
    assert_eq!(out.len(), n as usize);
    let tracks = engine.drain_traces();
    assert_eq!(tracks.len(), 4, "one track per shard worker");
    // Tracks come back in ascending worker-id order, matching the
    // deterministic metrics merge.
    for (i, (shard, _)) in tracks.iter().enumerate() {
        assert_eq!(*shard, i);
    }
    let total: usize = tracks.iter().map(|(_, evs)| evs.len()).sum();
    assert!(total > 0, "sharded serve recorded no events");
    let text = chrome_trace(&tracks).to_string();
    let doc = json::parse(&text).unwrap();
    let (n_events, n_tracks) = check_trace_doc(&doc).unwrap();
    assert!(n_events > 0);
    assert_eq!(n_tracks, 4);
    // --validate-every ran on every shard that ticked at least 3 times;
    // the merged snapshot must have seen at least one validation pass.
    let snap = engine.metrics_snapshot();
    assert!(snap.counter(Counter::ValidationsRun) > 0);
    assert_eq!(
        snap.counter(Counter::Retired),
        stats.iter().map(|s| s.served).sum::<usize>() as u64
    );
}

#[test]
fn validate_every_tick_passes_on_a_healthy_arena() {
    let engine = Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap();
    engine.obs().set_enabled(true);
    let out = Server::new(&engine, Policy::Continuous { max_active: 2 })
        .with_validate_every(1)
        .serve(requests(6))
        .unwrap();
    assert_eq!(out.len(), 6);
    let snap = engine.metrics_snapshot();
    let ticks = snap.counter(Counter::TicksRun);
    assert!(ticks > 0);
    assert_eq!(
        snap.counter(Counter::ValidationsRun),
        ticks,
        "--validate-every 1 must validate on every tick"
    );
}
