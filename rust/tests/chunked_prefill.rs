//! Differential harness for the chunked-prefill lane: ingesting a
//! prompt `chunk` positions per scheduler tick is scheduling only, so
//! served tokens must be BIT-FOR-BIT the unchunked run's on both host
//! backends — across chunk sizes that pin every boundary (one position,
//! spans straddling a cache-block boundary, the whole prompt in one
//! tick, chunk larger than the prompt), composed with copy-on-write
//! prefix adoption, and under arena pressure where chunked sessions are
//! preempted and re-prefilled.
//!
//! Why exactness holds: a session's fed sequence is a pure function of
//! its own request (prompt tokens in order, then its own greedy
//! continuations), and `decode_span` is pinned bit-for-bit against the
//! sequential `decode_step` loop — the chunk size changes only WHEN
//! positions are fed relative to other sessions, never WHAT any session
//! feeds. Preemption re-prefills deterministically, so even eviction
//! timing differences cannot leak into tokens.

use pim_llm::runtime::{Artifacts, BackendKind, Engine};
use pim_llm::serving::{Policy, Request, Response, Server};

const SEED: u64 = 23;
const HOST_BACKENDS: [BackendKind; 2] = [BackendKind::Reference, BackendKind::Packed];

/// Deterministic per-request prompts (id-dependent, so sessions are
/// distinguishable) of one shared length.
fn requests(n: u64, prompt_len: usize, n_new: usize) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len)
                .map(|i| ((id as usize * 13 + i * 7) % 29 + 1) as i32)
                .collect(),
            n_new,
        })
        .collect()
}

/// Same workload shape as `repro serve --prefix-cache`: a common system
/// prefix over the first half of every prompt, per-request tail after.
fn shared_prefix_requests(n: u64, prompt_len: usize, n_new: usize) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len)
                .map(|i| {
                    if i < prompt_len / 2 {
                        ((i * 7) % 29 + 1) as i32
                    } else {
                        ((id as usize * 13 + i * 7) % 29 + 1) as i32
                    }
                })
                .collect(),
            n_new,
        })
        .collect()
}

fn assert_tokens_match(base: &[Response], out: &[Response], label: &str) {
    assert_eq!(base.len(), out.len(), "{label}: response count");
    for b in base {
        let r = out
            .iter()
            .find(|r| r.id == b.id)
            .unwrap_or_else(|| panic!("{label}: request {} missing", b.id));
        assert_eq!(b.tokens, r.tokens, "{label}: request {}", b.id);
    }
}

#[test]
fn every_chunk_size_matches_unchunked_on_both_backends() {
    for kind in HOST_BACKENDS {
        let engine =
            Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 0).unwrap();
        let reqs = requests(4, 10, 6);
        let base = Server::new(&engine, Policy::Continuous { max_active: 4 })
            .serve(reqs.clone())
            .unwrap();
        // 1 = classic pacing through the lane path; 3 and 5 straddle the
        // 4-position block boundary mid-span; 10 = the whole prompt in
        // one tick; 64 = chunk far larger than the prompt (clamped).
        for chunk in [1usize, 3, 5, 10, 64] {
            for policy in [
                Policy::Continuous { max_active: 4 },
                Policy::Batched { batch: 4 },
                Policy::Fifo,
            ] {
                let out = Server::new(&engine, policy)
                    .with_prefill_chunk(chunk)
                    .serve(reqs.clone())
                    .unwrap();
                assert_tokens_match(
                    &base,
                    &out,
                    &format!("{kind:?} chunk {chunk} {policy:?}"),
                );
            }
        }
        let st = engine.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks, "{kind:?}: leaked blocks");
    }
}

#[test]
fn chunked_prefill_composes_with_prefix_adoption() {
    for kind in HOST_BACKENDS {
        let reqs = shared_prefix_requests(5, 12, 5);
        let cold =
            Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 0).unwrap();
        // max_active 2 staggers admission: the first wave's completed
        // prefills are indexed before the later requests are admitted,
        // so those requests actually adopt the shared prefix (an
        // admit-everyone-at-once schedule would find an empty index).
        let base = Server::new(&cold, Policy::Continuous { max_active: 2 })
            .serve(reqs.clone())
            .unwrap();
        // Fresh cached engine per chunk size so every run sees the same
        // empty index; chunks straddle both the adopted-prefix boundary
        // (6 positions = 1.5 blocks) and the block boundary.
        for chunk in [1usize, 3, 8, 12] {
            let warm =
                Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 0).unwrap();
            assert!(warm.enable_prefix_cache(0));
            let out = Server::new(&warm, Policy::Continuous { max_active: 2 })
                .with_prefill_chunk(chunk)
                .serve(reqs.clone())
                .unwrap();
            assert_tokens_match(&base, &out, &format!("{kind:?} cached chunk {chunk}"));
            let cached: usize = out.iter().map(|r| r.cached_tokens).sum();
            assert!(
                cached > 0,
                "{kind:?} chunk {chunk}: the shared prefix never hit the cache"
            );
        }
    }
}

#[test]
fn chunked_prefill_survives_preemption_re_prefill() {
    for kind in HOST_BACKENDS {
        let roomy =
            Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 0).unwrap();
        let reqs = requests(6, 8, 8);
        let base = Server::new(&roomy, Policy::Fifo).serve(reqs.clone()).unwrap();
        // 6 requests x 16 positions = 4 blocks each against 12 blocks:
        // continuous batching must preempt, and the re-prefill re-ingests
        // the prompt through the SAME chunked lane.
        for chunk in [1usize, 3, 8] {
            let tight =
                Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 12).unwrap();
            let out = Server::new(&tight, Policy::Continuous { max_active: 6 })
                .with_prefill_chunk(chunk)
                .serve(reqs.clone())
                .unwrap();
            assert!(
                out.iter().map(|r| r.evictions).sum::<u32>() > 0,
                "{kind:?} chunk {chunk}: 12 blocks cannot hold 6 x 4-block sessions"
            );
            assert_tokens_match(&base, &out, &format!("{kind:?} tight chunk {chunk}"));
            let st = tight.arena_status();
            assert_eq!(st.free_blocks, st.total_blocks, "{kind:?}: leaked blocks");
        }
    }
}
