//! Property tests for the packed-bitplane backend: packing ternary
//! weights into popcount bitplanes is a REPRESENTATION change, never a
//! numerics change. For random synthetic models the `packed` backend
//! must be bit-for-bit identical to `reference` — logits AND KV caches —
//! on every path:
//!
//! * single `decode_step`,
//! * full greedy generation (`TinyDecoder`),
//! * ragged `decode_batch` (`BatchDecoder`), including the
//!   column-striped threaded kernel path,
//! * batched serving (`Server` with `Policy::Batched`).
//!
//! Plus `pack -> unpack` round trips over adversarial shapes at the
//! quant-subsystem level.
//!
//! The offline build has no proptest; randomness comes from the
//! in-crate SplitMix64 (`util::rng`) with fixed seeds, so every failure
//! is reproducible.

use pim_llm::quant::{pack_verified, unpack};
use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, BatchDecoder, Engine, TinyDecoder};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::rng::Rng;

/// Both engines over the SAME artifacts.
fn engine_pair(artifacts: Artifacts) -> (Engine, Engine) {
    let reference =
        Engine::load_with(artifacts.clone(), BackendKind::Reference).expect("reference engine");
    let packed = Engine::load_with(artifacts, BackendKind::Packed).expect("packed engine");
    (reference, packed)
}

/// A random small-but-varied model shape. Dimensions deliberately avoid
/// multiples of 64 most of the time so the bitplane padding lanes are
/// exercised (d, d_ff, vocab are all contraction or output dims of some
/// projection).
fn random_model(rng: &mut Rng) -> ModelInfo {
    let h = [1usize, 2, 4][rng.range(0, 2)];
    let d = h * [3usize, 5, 8, 16, 17][rng.range(0, 4)];
    ModelInfo {
        vocab: rng.range(8, 90),
        d,
        h,
        d_ff: rng.range(9, 100),
        n_layers: rng.range(1, 2),
        max_ctx: rng.range(8, 16),
        eps: 1e-5,
    }
}

#[test]
fn packed_equals_reference_over_20_random_models() {
    // >= 20 random synthetic models; for each, single-step equality
    // (logits + caches) and a short ragged batched run.
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0xA5A5_1234).wrapping_add(7));
        let model = random_model(&mut rng);
        let artifacts = Artifacts::synthetic_with(seed, model.clone())
            .unwrap_or_else(|e| panic!("seed {seed} model {model:?}: {e}"));
        let (reference, packed) = engine_pair(artifacts);
        let vocab = reference.vocab() as i32;

        // Single step, bitwise, caches included (compared through the
        // arena's contiguous reassembly).
        let tok = rng.range(0, vocab as usize - 1) as i32;
        let rs = reference.new_session().unwrap();
        let ps = packed.new_session().unwrap();
        let r = reference.decode_step(rs, tok, 0).unwrap();
        let p = packed.decode_step(ps, tok, 0).unwrap();
        assert_eq!(r, p, "seed {seed} {model:?}: step logits");
        assert_eq!(
            reference.gather_session(rs).unwrap(),
            packed.gather_session(ps).unwrap(),
            "seed {seed} {model:?}: step caches"
        );

        // Ragged batched decode: mixed prompt lengths and budgets.
        let lanes = rng.range(1, 5);
        let prompts: Vec<Vec<i32>> = (0..lanes)
            .map(|_| {
                (0..rng.range(0, 4))
                    .map(|_| rng.range(0, vocab as usize - 1) as i32)
                    .collect()
            })
            .collect();
        let n_new: Vec<usize> = (0..lanes).map(|_| rng.range(0, 4)).collect();
        let mut br = BatchDecoder::new(&reference);
        br.generate(&prompts, &n_new).unwrap();
        let mut bp = BatchDecoder::new(&packed);
        bp.generate(&prompts, &n_new).unwrap();
        for lane in 0..lanes {
            assert_eq!(
                br.session(lane).tokens,
                bp.session(lane).tokens,
                "seed {seed} lane {lane}: batched tokens"
            );
            assert_eq!(
                br.session(lane).last_logits,
                bp.session(lane).last_logits,
                "seed {seed} lane {lane}: batched logits"
            );
        }
    }
}

#[test]
fn full_generation_matches_reference_exactly() {
    // Multi-step greedy generation: one diverging bit anywhere in any
    // step would change the token stream, so exact token + logit
    // equality over a full generation is an end-to-end bitwise check.
    for seed in [2u64, 11, 29] {
        let (reference, packed) = engine_pair(Artifacts::synthetic(seed).unwrap());
        let mut tr = TinyDecoder::new(&reference).unwrap();
        tr.generate(&[1, 5, 9], 12).unwrap();
        let mut tp = TinyDecoder::new(&packed).unwrap();
        tp.generate(&[1, 5, 9], 12).unwrap();
        assert_eq!(tr.tokens, tp.tokens, "seed {seed}: generation tokens");
        assert_eq!(
            tr.last_logits, tp.last_logits,
            "seed {seed}: final logits"
        );
    }
}

#[test]
fn packed_reproduces_the_recorded_golden_generation() {
    // The synthetic golden was produced by the reference executor at
    // synthesis time; the packed backend must reproduce it exactly.
    let packed = Engine::load_with(Artifacts::synthetic(31).unwrap(), BackendKind::Packed)
        .unwrap();
    pim_llm::runtime::decoder::validate_golden(&packed).expect("golden on packed backend");
}

#[test]
fn striped_kernel_path_matches_on_a_sized_model() {
    // Large enough that BOTH backends cross the PAR_MAC_THRESHOLD
    // column-striping threshold at batch 8 (8 * 256 * 1024 = 2^21 MACs
    // on the FF matrices): the threaded popcount walk must agree with
    // the threaded dense walk bit for bit. d=256 also exercises
    // multi-word (4 x 64-row) columns.
    let model = ModelInfo {
        vocab: 64,
        d: 256,
        h: 4,
        d_ff: 1024,
        n_layers: 1,
        max_ctx: 16,
        eps: 1e-5,
    };
    let (reference, packed) = engine_pair(Artifacts::synthetic_with(5, model).unwrap());
    let prompts: Vec<Vec<i32>> = (0..8).map(|i| vec![i + 1, (i * 3) % 60]).collect();
    let n_new = vec![2usize; 8];
    let mut br = BatchDecoder::new(&reference);
    br.generate(&prompts, &n_new).unwrap();
    let mut bp = BatchDecoder::new(&packed);
    bp.generate(&prompts, &n_new).unwrap();
    for lane in 0..prompts.len() {
        assert_eq!(br.session(lane).tokens, bp.session(lane).tokens, "lane {lane}");
        assert_eq!(
            br.session(lane).last_logits,
            bp.session(lane).last_logits,
            "lane {lane}"
        );
    }
}

#[test]
fn batched_serving_is_identical_across_backends() {
    // The serving stack (admission, batched scheduler ticks, greedy
    // continuation) on the packed engine must produce byte-identical
    // responses to the reference engine, degenerate requests included.
    let (reference, packed) = engine_pair(Artifacts::synthetic(17).unwrap());
    let requests = vec![
        Request { id: 0, prompt: vec![1, 2, 3, 4, 5], n_new: 4 },
        Request { id: 1, prompt: vec![], n_new: 3 },
        Request { id: 2, prompt: vec![9], n_new: 0 },
        Request { id: 3, prompt: vec![6, 2], n_new: 6 },
        Request { id: 4, prompt: vec![], n_new: 0 },
    ];
    for policy in [
        Policy::Batched { batch: 3 },
        Policy::Continuous { max_active: 3 },
        Policy::RoundRobin { max_active: 2 },
        Policy::Fifo,
    ] {
        let r = Server::new(&reference, policy).serve(requests.clone()).unwrap();
        let p = Server::new(&packed, policy).serve(requests.clone()).unwrap();
        assert_eq!(r.len(), p.len(), "{policy:?}");
        for resp in &r {
            let q = p.iter().find(|q| q.id == resp.id).unwrap();
            assert_eq!(resp.tokens, q.tokens, "request {} under {policy:?}", resp.id);
        }
    }
}

#[test]
fn artifact_loaded_weights_match_reference_exactly() {
    // The third weight path: reference (dense f32) vs packed lowered in
    // memory vs packed loaded from a .tpk artifact (mmap'd planes).
    // All three must generate bit-identically — the artifact round trip
    // is a representation change squared, never a numerics change.
    for seed in [3u64, 23] {
        let artifacts = Artifacts::synthetic(seed).unwrap();
        let lowered = pim_llm::quant::PackedModel::lower(&artifacts).unwrap();
        let path = std::env::temp_dir().join(format!(
            "pimllm-equiv-{}-{seed}.tpk",
            std::process::id()
        ));
        pim_llm::quant::write_tpk(&path, &lowered, &artifacts.manifest).unwrap();

        let (reference, packed) = engine_pair(Artifacts::synthetic(seed).unwrap());
        let from_tpk =
            Engine::load_packed_artifact(Artifacts::synthetic(seed).unwrap(), &path, 0, 0)
                .expect("engine from .tpk");
        std::fs::remove_file(&path).ok(); // mmap survives the unlink on unix

        let mut tr = TinyDecoder::new(&reference).unwrap();
        tr.generate(&[2, 7, 1], 10).unwrap();
        let mut tp = TinyDecoder::new(&packed).unwrap();
        tp.generate(&[2, 7, 1], 10).unwrap();
        let mut ta = TinyDecoder::new(&from_tpk).unwrap();
        ta.generate(&[2, 7, 1], 10).unwrap();
        assert_eq!(tr.tokens, tp.tokens, "seed {seed}: lowered tokens");
        assert_eq!(tr.tokens, ta.tokens, "seed {seed}: artifact tokens");
        assert_eq!(tr.last_logits, ta.last_logits, "seed {seed}: artifact logits");
    }
}

#[test]
fn pack_unpack_round_trips_adversarial_shapes() {
    // The quant-level contract, exercised from outside the crate: k not
    // a multiple of 64, n=1, k=1, word-boundary straddles.
    let mut rng = Rng::new(0xC0DE);
    for (k, n) in [
        (1usize, 1usize),
        (1, 13),
        (13, 1),
        (63, 2),
        (64, 2),
        (65, 2),
        (127, 1),
        (129, 3),
        (300, 7),
    ] {
        // Rng::range is INCLUSIVE: [0, 2] - 1 = {-1, 0, 1}.
        let w: Vec<f32> = (0..k * n).map(|_| rng.range(0, 2) as f32 - 1.0).collect();
        let planes = pack_verified(&w, k, n, 0.8).unwrap_or_else(|e| panic!("{k}x{n}: {e}"));
        assert_eq!(unpack(&planes), w, "{k}x{n}");
        assert_eq!(planes.words_per_col, k.div_ceil(64), "{k}x{n}");
        // Census agrees with the dense source.
        let plus = w.iter().filter(|&&x| x == 1.0).count() as u64;
        let minus = w.iter().filter(|&&x| x == -1.0).count() as u64;
        assert_eq!(planes.nnz(), (plus, minus), "{k}x{n}");
    }
}
