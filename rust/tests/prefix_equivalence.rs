//! Differential harness for copy-on-write prefix sharing: adopting a
//! cached prompt prefix and skipping its prefill decode must be
//! BIT-FOR-BIT identical — logits at every remaining step AND the
//! final gathered caches — to cold prefill, on both host backends.
//!
//! Why exactness holds: K/V rows at position `p` depend only on tokens
//! `0..=p`, the decode step is bit-deterministic (PR 2/3/4 chains), and
//! adoption hands the session either the very blocks an identical
//! prefix wrote (full blocks, shared read-only) or a copy whose matched
//! rows are those bytes and whose remaining rows are zeroed — exactly
//! cold-prefill state. This suite pins that argument over random
//! models, block lengths {1, 3, default}, prefix lengths straddling
//! block boundaries (0, 1, block_len-1, block_len, block_len+1, and
//! beyond), and evict -> re-admit -> re-share cycles, plus end-to-end
//! serving equivalence with the cache on vs off.

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{Artifacts, BackendKind, Engine};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::rng::Rng;

const HOST_BACKENDS: [BackendKind; 2] = [BackendKind::Reference, BackendKind::Packed];

/// A random small-but-varied model shape (dims chosen so block
/// boundaries land mid-head, like the paged-equivalence suite).
fn random_model(rng: &mut Rng) -> ModelInfo {
    let h = [1usize, 2, 4][rng.range(0, 2)];
    ModelInfo {
        vocab: rng.range(8, 60),
        d: h * [3usize, 5, 8][rng.range(0, 2)],
        h,
        d_ff: rng.range(9, 40),
        n_layers: rng.range(1, 2),
        max_ctx: rng.range(12, 24),
        eps: 1e-5,
    }
}

/// Cold-prefill oracle: a fresh session decoding `tokens` from zero on
/// a cache-less engine; returns per-step logits and the final caches.
fn cold_run(engine: &Engine, tokens: &[i32]) -> (Vec<Vec<f32>>, (Vec<f32>, Vec<f32>)) {
    let s = engine.new_session().unwrap();
    let logits: Vec<Vec<f32>> = tokens
        .iter()
        .enumerate()
        .map(|(pos, &t)| engine.decode_step(s, t, pos as i32).unwrap())
        .collect();
    let caches = engine.gather_session(s).unwrap();
    engine.free_session(s).unwrap();
    (logits, caches)
}

/// Warm a prefix-cached engine with `donor` (full prefill + index
/// insert), then run `prompt` through adoption and assert bitwise
/// equality with the cold oracle from `oracle_engine`.
fn assert_adopted_matches_cold(
    warm: &Engine,
    oracle_engine: &Engine,
    prompt: &[i32],
    label: &str,
) {
    let (want_logits, want_caches) = cold_run(oracle_engine, prompt);
    let s = warm.new_session().unwrap();
    let skipped = warm.prefix_adopt(s, prompt).unwrap();
    assert!(
        skipped < prompt.len().max(1),
        "{label}: adoption must leave at least one token to decode \
         (skipped {skipped} of {})",
        prompt.len()
    );
    for (pos, &t) in prompt.iter().enumerate().skip(skipped) {
        let got = warm.decode_step(s, t, pos as i32).unwrap();
        assert_eq!(
            got, want_logits[pos],
            "{label}: logits diverged at pos {pos} (skipped {skipped})"
        );
    }
    assert_eq!(
        warm.gather_session(s).unwrap(),
        want_caches,
        "{label}: gathered caches diverged (skipped {skipped})"
    );
    warm.free_session(s).unwrap();
    warm.debug_validate().unwrap();
}

#[test]
fn shared_prefix_decode_is_bitwise_cold_prefill() {
    // The core sweep: random models x block lens x prefix lengths that
    // straddle block boundaries, on both host backends.
    for seed in 0..4u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0xA5A5_5A5A).wrapping_add(17));
        let model = random_model(&mut rng);
        let max_ctx = model.max_ctx;
        for kind in HOST_BACKENDS {
            for block_len in [1usize, 3, 0] {
                let artifacts = || Artifacts::synthetic_with(seed, model.clone()).unwrap();
                let warm =
                    Engine::load_with_arena(artifacts(), kind, block_len, 64).unwrap();
                assert!(warm.enable_prefix_cache(0));
                let cold =
                    Engine::load_with_arena(artifacts(), kind, block_len, 64).unwrap();
                let bl = warm.block_len();

                // Donor prompt: long enough for several full blocks.
                let donor_len = (3 * bl + 2).min(max_ctx - 1);
                let donor: Vec<i32> = (0..donor_len)
                    .map(|_| rng.range(0, model.vocab - 1) as i32)
                    .collect();
                let ds = warm.new_session().unwrap();
                for (pos, &t) in donor.iter().enumerate() {
                    warm.decode_step(ds, t, pos as i32).unwrap();
                }
                warm.prefix_insert(ds, &donor).unwrap();

                // Shared-prefix lengths straddling block boundaries: the
                // adopter's prompt agrees with the donor for `shared`
                // tokens, then diverges (token +1 mod vocab).
                for shared in [0usize, 1, bl.saturating_sub(1), bl, bl + 1, donor_len] {
                    let shared = shared.min(donor_len);
                    let total = (shared + bl + 1).min(max_ctx - 1).max(1);
                    let prompt: Vec<i32> = (0..total)
                        .map(|i| {
                            if i < shared {
                                donor[i]
                            } else {
                                let base = donor.get(i).copied().unwrap_or(0);
                                (base + 1).rem_euclid(model.vocab as i32)
                            }
                        })
                        .collect();
                    assert_adopted_matches_cold(
                        &warm,
                        &cold,
                        &prompt,
                        &format!(
                            "seed {seed} {kind:?} bl {bl} shared {shared}"
                        ),
                    );
                }
                warm.free_session(ds).unwrap();
                warm.debug_validate().unwrap();
            }
        }
    }
}

#[test]
fn evict_readmit_reshare_cycles_stay_bitwise() {
    // The continuous scheduler's life cycle in miniature, repeated:
    // adopt a shared prefix, decode partway, evict (free the session),
    // re-admit with a fresh adoption, run to completion — every cycle
    // must reproduce the cold logits and caches exactly, and the arena
    // must stay balanced throughout.
    for kind in HOST_BACKENDS {
        let artifacts = || Artifacts::synthetic(0xE1).unwrap();
        let warm = Engine::load_with_arena(artifacts(), kind, 4, 32).unwrap();
        assert!(warm.enable_prefix_cache(0));
        let cold = Engine::load_with_arena(artifacts(), kind, 4, 32).unwrap();

        let donor: Vec<i32> = vec![9, 2, 7, 7, 1, 30, 12, 5, 44, 3];
        let ds = warm.new_session().unwrap();
        for (pos, &t) in donor.iter().enumerate() {
            warm.decode_step(ds, t, pos as i32).unwrap();
        }
        warm.prefix_insert(ds, &donor).unwrap();
        warm.free_session(ds).unwrap(); // donor retires; index pins live on

        let mut prompt = donor.clone();
        prompt.extend([13, 21, 34]); // shared prefix + fresh tail
        let (want_logits, want_caches) = cold_run(&cold, &prompt);

        for cycle in 0..3 {
            // Partial run, evicted mid-flight.
            let s = warm.new_session().unwrap();
            let skipped = warm.prefix_adopt(s, &prompt).unwrap();
            assert_eq!(skipped, 8, "cycle {cycle}: 2 full blocks cached");
            let stop = skipped + 2;
            for (pos, &t) in prompt.iter().enumerate().take(stop).skip(skipped) {
                assert_eq!(
                    warm.decode_step(s, t, pos as i32).unwrap(),
                    want_logits[pos],
                    "cycle {cycle} pre-evict pos {pos}"
                );
            }
            warm.free_session(s).unwrap(); // evict
            warm.debug_validate().unwrap();

            // Re-admit: re-share and run to completion.
            let s = warm.new_session().unwrap();
            assert_eq!(warm.prefix_adopt(s, &prompt).unwrap(), skipped);
            for (pos, &t) in prompt.iter().enumerate().skip(skipped) {
                assert_eq!(
                    warm.decode_step(s, t, pos as i32).unwrap(),
                    want_logits[pos],
                    "cycle {cycle} post-readmit pos {pos}"
                );
            }
            assert_eq!(
                warm.gather_session(s).unwrap(),
                want_caches,
                "cycle {cycle}: caches after re-share"
            );
            warm.free_session(s).unwrap();
            warm.debug_validate().unwrap();
        }

        // Reclaiming the whole index returns every pinned block.
        warm.prefix_reclaim(usize::MAX).unwrap();
        let st = warm.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks, "{kind:?}");
        assert_eq!(st.pinned_blocks, 0, "{kind:?}");

        // With the index empty the same prompt is a clean miss and the
        // cold path still reproduces the oracle (re-insertable after).
        let s = warm.new_session().unwrap();
        assert_eq!(warm.prefix_adopt(s, &prompt).unwrap(), 0);
        for (pos, &t) in prompt.iter().enumerate() {
            assert_eq!(
                warm.decode_step(s, t, pos as i32).unwrap(),
                want_logits[pos],
                "post-reclaim pos {pos}"
            );
        }
        warm.prefix_insert(s, &prompt).unwrap();
        warm.free_session(s).unwrap();
        let s2 = warm.new_session().unwrap();
        assert!(warm.prefix_adopt(s2, &prompt).unwrap() > 0, "re-share after re-insert");
        warm.free_session(s2).unwrap();
        warm.debug_validate().unwrap();
    }
}

#[test]
fn partial_tail_adoption_copies_exactly_once() {
    // A prompt ending mid-block adopts the donor's tail block via COW:
    // the copy must not disturb the donor, and both sessions' caches
    // must equal their own cold runs bitwise.
    for kind in HOST_BACKENDS {
        let artifacts = || Artifacts::synthetic(0x7A11).unwrap();
        let warm = Engine::load_with_arena(artifacts(), kind, 4, 32).unwrap();
        assert!(warm.enable_prefix_cache(0));
        let cold = Engine::load_with_arena(artifacts(), kind, 4, 32).unwrap();

        // Donor: 12 tokens = 3 full blocks indexed.
        let donor: Vec<i32> = vec![5, 1, 8, 2, 9, 9, 4, 7, 3, 6, 1, 2];
        let ds = warm.new_session().unwrap();
        for (pos, &t) in donor.iter().enumerate() {
            warm.decode_step(ds, t, pos as i32).unwrap();
        }
        warm.prefix_insert(ds, &donor).unwrap();
        let donor_caches = warm.gather_session(ds).unwrap();

        // Adopter shares 2 full blocks + 2 rows of the third (prompt
        // len 11 -> usable 10 = 2*4 + 2), then generates.
        let prompt = donor[..11].to_vec();
        let (want_logits, want_caches) = cold_run(&cold, &prompt);
        let s = warm.new_session().unwrap();
        let skipped = warm.prefix_adopt(s, &prompt).unwrap();
        assert_eq!(skipped, 10, "{kind:?}: 2 full blocks + 2 tail rows");
        for (pos, &t) in prompt.iter().enumerate().skip(skipped) {
            assert_eq!(warm.decode_step(s, t, pos as i32).unwrap(), want_logits[pos]);
        }
        assert_eq!(warm.gather_session(s).unwrap(), want_caches, "{kind:?}");
        // The donor's own blocks are untouched by the adopter's COW.
        assert_eq!(warm.gather_session(ds).unwrap(), donor_caches, "{kind:?}");
        warm.free_session(s).unwrap();
        warm.free_session(ds).unwrap();
        warm.debug_validate().unwrap();
    }
}

#[test]
fn serving_with_prefix_cache_matches_cache_off_end_to_end() {
    // Whole-stack acceptance on both host backends and both batch-wave
    // schedulers: a prefix-heavy request stream (few distinct system
    // prompts) served with the cache on must produce exactly the
    // cache-off tokens, while actually saving prefill work.
    let mut rng = Rng::new(0x5EED);
    let systems: [Vec<i32>; 2] = [
        (0..9).map(|_| rng.range(1, 60) as i32).collect(),
        (0..9).map(|_| rng.range(1, 60) as i32).collect(),
    ];
    let requests: Vec<Request> = (0..10u64)
        .map(|id| {
            let mut prompt = systems[(id % 2) as usize].clone();
            prompt.push(id as i32 + 1);
            Request { id, prompt, n_new: rng.range(2, 6) }
        })
        .collect();
    for kind in HOST_BACKENDS {
        let engine_with = |cache: bool| {
            let e = Engine::load_with_arena(
                Artifacts::synthetic(0x5EED).unwrap(),
                kind,
                3,
                64,
            )
            .unwrap();
            if cache {
                assert!(e.enable_prefix_cache(0));
            }
            e
        };
        let off = engine_with(false);
        let baseline = Server::new(&off, Policy::Fifo).serve(requests.clone()).unwrap();
        for policy in [
            Policy::Batched { batch: 4 },
            Policy::Continuous { max_active: 4 },
        ] {
            let on = engine_with(true);
            let out = Server::new(&on, policy).serve(requests.clone()).unwrap();
            for b in &baseline {
                let r = out.iter().find(|r| r.id == b.id).unwrap();
                assert_eq!(b.tokens, r.tokens, "{kind:?} {policy:?} request {}", b.id);
            }
            let stats = on.prefix_stats().unwrap();
            assert!(
                stats.saved_tokens > 0,
                "{kind:?} {policy:?}: the shared system prompts must hit \
                 (saved {} / hits {} / misses {})",
                stats.saved_tokens,
                stats.hits,
                stats.misses
            );
            on.debug_validate().unwrap();
        }
    }
}
