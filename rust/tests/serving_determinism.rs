//! Determinism stress for the serving front end: repeated runs of the
//! threaded server over a mixed-length request set — including
//! zero-generation requests (`n_new == 0`), empty prompts, and a
//! zero-work request (both at once) — must produce byte-identical token
//! streams every time, under both the round-robin and batched
//! schedulers. This is what flushed out the empty-logits argmax panic
//! and zero-work admission hang of the pre-batching serving loop.

use pim_llm::runtime::{Artifacts, Engine};
use pim_llm::serving::{serve_threaded_policy, serve_threaded_with, Policy, Request, Response};

const SEED: u64 = 0xDE7;
const RUNS: usize = 10;

/// Deliberately awkward request mix: ragged lengths, degenerate shapes.
fn mixed_requests() -> Vec<Request> {
    vec![
        Request { id: 0, prompt: vec![1, 2, 3, 4, 5, 6], n_new: 5 },
        Request { id: 1, prompt: vec![], n_new: 4 },
        Request { id: 2, prompt: vec![7], n_new: 0 },
        Request { id: 3, prompt: vec![], n_new: 0 },
        Request { id: 4, prompt: vec![9, 8, 7], n_new: 7 },
        Request { id: 5, prompt: vec![2; 10], n_new: 1 },
        Request { id: 6, prompt: vec![5, 5], n_new: 6 },
        Request { id: 7, prompt: vec![63, 1], n_new: 3 },
    ]
}

/// The byte-comparable part of a response set: ids + token streams in
/// returned order (timing fields legitimately vary between runs).
fn token_streams(responses: &[Response]) -> Vec<(u64, Vec<i32>)> {
    responses
        .iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect()
}

fn run_threaded(policy: Policy) -> Vec<(u64, Vec<i32>)> {
    let out = serve_threaded_policy(
        || Engine::load(Artifacts::synthetic(SEED)?),
        mixed_requests(),
        3,
        policy,
    )
    .expect("threaded serve");
    token_streams(&out)
}

#[test]
fn threaded_round_robin_byte_identical_across_10_runs() {
    let golden = run_threaded(Policy::RoundRobin { max_active: 2 });
    assert_eq!(golden.len(), mixed_requests().len());
    for run in 1..RUNS {
        assert_eq!(
            golden,
            run_threaded(Policy::RoundRobin { max_active: 2 }),
            "round-robin run {run} diverged"
        );
    }
}

#[test]
fn threaded_batched_byte_identical_across_10_runs() {
    let golden = run_threaded(Policy::Batched { batch: 3 });
    assert_eq!(golden.len(), mixed_requests().len());
    for run in 1..RUNS {
        assert_eq!(
            golden,
            run_threaded(Policy::Batched { batch: 3 }),
            "batched run {run} diverged"
        );
    }
}

#[test]
fn threaded_continuous_byte_identical_across_10_runs() {
    let golden = run_threaded(Policy::Continuous { max_active: 3 });
    assert_eq!(golden.len(), mixed_requests().len());
    for run in 1..RUNS {
        assert_eq!(
            golden,
            run_threaded(Policy::Continuous { max_active: 3 }),
            "continuous run {run} diverged"
        );
    }
}

#[test]
fn continuous_under_preemption_byte_identical_across_runs() {
    // A deliberately tight arena (block_len 4, 8 blocks) so the
    // continuous scheduler preempts mid-run: evict -> requeue ->
    // re-prefill must be deterministic, token-for-token, every time.
    let run = || {
        let engine = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            pim_llm::runtime::BackendKind::Reference,
            4,
            8,
        )
        .unwrap();
        let out = pim_llm::serving::Server::new(&engine, Policy::Continuous { max_active: 6 })
            .serve(mixed_requests())
            .unwrap();
        let mut streams = token_streams(&out);
        streams.sort_by_key(|(id, _)| *id);
        streams
    };
    let golden = run();
    assert_eq!(golden.len(), mixed_requests().len());
    for r in 1..RUNS {
        assert_eq!(golden, run(), "tight-arena continuous run {r} diverged");
    }
}

#[test]
fn schedulers_and_worker_counts_agree_on_the_mixed_set() {
    // Same tokens whatever the worker count or scheduler — determinism
    // is a property of the numerics, not the deployment shape.
    let golden = run_threaded(Policy::RoundRobin { max_active: 2 });
    for workers in [1usize, 2, 4, 8] {
        for policy in [
            Policy::Fifo,
            Policy::RoundRobin { max_active: 4 },
            Policy::Batched { batch: 4 },
            Policy::Continuous { max_active: 4 },
        ] {
            let out = serve_threaded_policy(
                || Engine::load(Artifacts::synthetic(SEED)?),
                mixed_requests(),
                workers,
                policy,
            )
            .expect("threaded serve");
            assert_eq!(
                golden,
                token_streams(&out),
                "{workers} workers under {policy:?} diverged"
            );
        }
    }
}

#[test]
fn degenerate_requests_complete_with_correct_shapes() {
    let out = serve_threaded_with(
        || Engine::load(Artifacts::synthetic(SEED)?),
        mixed_requests(),
        2,
        3,
    )
    .expect("threaded serve");
    let by_id = |id: u64| out.iter().find(|r| r.id == id).expect("response");
    for req in mixed_requests() {
        let r = by_id(req.id);
        assert_eq!(
            r.tokens.len(),
            req.prompt.len() + req.n_new,
            "request {}",
            req.id
        );
        assert_eq!(&r.tokens[..req.prompt.len()], &req.prompt[..]);
    }
    // Zero-work request: completes with no tokens and sane timing.
    let r = by_id(3);
    assert!(r.tokens.is_empty());
    assert!(r.service_s >= 0.0 && r.ttft_s >= 0.0);
}
