//! Determinism stress for the serving front end: repeated runs of the
//! threaded server over a mixed-length request set — including
//! zero-generation requests (`n_new == 0`), empty prompts, and a
//! zero-work request (both at once) — must produce byte-identical token
//! streams every time, under both the round-robin and batched
//! schedulers. This is what flushed out the empty-logits argmax panic
//! and zero-work admission hang of the pre-batching serving loop.

use pim_llm::runtime::{Artifacts, Engine};
use pim_llm::serving::{serve_threaded_with, Policy, Request, Response, ThreadedServe};

const SEED: u64 = 0xDE7;
const RUNS: usize = 10;

/// Deliberately awkward request mix: ragged lengths, degenerate shapes.
fn mixed_requests() -> Vec<Request> {
    vec![
        Request { id: 0, prompt: vec![1, 2, 3, 4, 5, 6], n_new: 5 },
        Request { id: 1, prompt: vec![], n_new: 4 },
        Request { id: 2, prompt: vec![7], n_new: 0 },
        Request { id: 3, prompt: vec![], n_new: 0 },
        Request { id: 4, prompt: vec![9, 8, 7], n_new: 7 },
        Request { id: 5, prompt: vec![2; 10], n_new: 1 },
        Request { id: 6, prompt: vec![5, 5], n_new: 6 },
        Request { id: 7, prompt: vec![63, 1], n_new: 3 },
    ]
}

/// The byte-comparable part of a response set: ids + token streams in
/// returned order (timing fields legitimately vary between runs).
fn token_streams(responses: &[Response]) -> Vec<(u64, Vec<i32>)> {
    responses
        .iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect()
}

fn run_threaded(policy: Policy) -> Vec<(u64, Vec<i32>)> {
    let out = ThreadedServe::new(|| Engine::load(Artifacts::synthetic(SEED)?))
        .workers(3)
        .policy(policy)
        .run(mixed_requests())
        .expect("threaded serve");
    token_streams(&out)
}

#[test]
fn threaded_round_robin_byte_identical_across_10_runs() {
    let golden = run_threaded(Policy::RoundRobin { max_active: 2 });
    assert_eq!(golden.len(), mixed_requests().len());
    for run in 1..RUNS {
        assert_eq!(
            golden,
            run_threaded(Policy::RoundRobin { max_active: 2 }),
            "round-robin run {run} diverged"
        );
    }
}

#[test]
fn threaded_batched_byte_identical_across_10_runs() {
    let golden = run_threaded(Policy::Batched { batch: 3 });
    assert_eq!(golden.len(), mixed_requests().len());
    for run in 1..RUNS {
        assert_eq!(
            golden,
            run_threaded(Policy::Batched { batch: 3 }),
            "batched run {run} diverged"
        );
    }
}

#[test]
fn threaded_continuous_byte_identical_across_10_runs() {
    let golden = run_threaded(Policy::Continuous { max_active: 3 });
    assert_eq!(golden.len(), mixed_requests().len());
    for run in 1..RUNS {
        assert_eq!(
            golden,
            run_threaded(Policy::Continuous { max_active: 3 }),
            "continuous run {run} diverged"
        );
    }
}

#[test]
fn continuous_under_preemption_byte_identical_across_runs() {
    // A deliberately tight arena (block_len 4, 8 blocks) so the
    // continuous scheduler preempts mid-run: evict -> requeue ->
    // re-prefill must be deterministic, token-for-token, every time.
    let run = || {
        let engine = Engine::load_with_arena(
            Artifacts::synthetic(SEED).unwrap(),
            pim_llm::runtime::BackendKind::Reference,
            4,
            8,
        )
        .unwrap();
        let out = pim_llm::serving::Server::new(&engine, Policy::Continuous { max_active: 6 })
            .serve(mixed_requests())
            .unwrap();
        let mut streams = token_streams(&out);
        streams.sort_by_key(|(id, _)| *id);
        streams
    };
    let golden = run();
    assert_eq!(golden.len(), mixed_requests().len());
    for r in 1..RUNS {
        assert_eq!(golden, run(), "tight-arena continuous run {r} diverged");
    }
}

#[test]
fn schedulers_and_worker_counts_agree_on_the_mixed_set() {
    // Same tokens whatever the worker count or scheduler — determinism
    // is a property of the numerics, not the deployment shape.
    let golden = run_threaded(Policy::RoundRobin { max_active: 2 });
    for workers in [1usize, 2, 4, 8] {
        for policy in [
            Policy::Fifo,
            Policy::RoundRobin { max_active: 4 },
            Policy::Batched { batch: 4 },
            Policy::Continuous { max_active: 4 },
        ] {
            let out = ThreadedServe::new(|| Engine::load(Artifacts::synthetic(SEED)?))
                .workers(workers)
                .policy(policy)
                .run(mixed_requests())
                .expect("threaded serve");
            assert_eq!(
                golden,
                token_streams(&out),
                "{workers} workers under {policy:?} diverged"
            );
        }
    }
}

/// Prefix-heavy workload: many requests, FEW distinct system prompts
/// (the shape the copy-on-write prefix cache serves), with ragged
/// suffixes and generation budgets plus a couple of degenerate shapes.
fn prefix_heavy_requests() -> Vec<Request> {
    let systems: [Vec<i32>; 2] = [
        vec![31, 7, 19, 2, 44, 5, 23, 11, 3, 16],
        vec![8, 8, 60, 1, 12, 39, 4, 27, 50, 9],
    ];
    (0..12u64)
        .map(|id| {
            let sys = &systems[(id % 2) as usize];
            let mut prompt = sys.clone();
            for j in 0..(id % 3) {
                prompt.push((id * 5 + j + 1) as i32);
            }
            Request {
                id,
                prompt,
                n_new: (id % 4) as usize + 1,
            }
        })
        .collect()
}

/// Engine replica factory with the prefix cache ON (block length 4 so
/// the 10-token system prompts span whole blocks) and a tight-ish arena
/// so the continuous runs also traverse reclaim/preemption.
fn prefix_engine(arena_blocks: usize) -> pim_llm::util::error::Result<Engine> {
    let e = Engine::load_with_arena(
        Artifacts::synthetic(SEED)?,
        pim_llm::runtime::BackendKind::Reference,
        4,
        arena_blocks,
    )?;
    assert!(e.enable_prefix_cache(0));
    Ok(e)
}

#[test]
fn prefix_cache_threaded_byte_identical_across_10_runs() {
    // The prefix cache introduces new scheduler state (index hits
    // change which positions prefill); determinism must survive it
    // under both decode_batch-per-tick policies, threaded, 10x.
    for policy in [Policy::Batched { batch: 4 }, Policy::Continuous { max_active: 4 }] {
        let run = || {
            let out = ThreadedServe::new(|| prefix_engine(64))
                .workers(3)
                .policy(policy)
                .run(prefix_heavy_requests())
                .expect("threaded prefix serve");
            token_streams(&out)
        };
        let golden = run();
        assert_eq!(golden.len(), prefix_heavy_requests().len());
        for r in 1..RUNS {
            assert_eq!(golden, run(), "{policy:?} prefix run {r} diverged");
        }
    }
}

#[test]
fn prefix_cache_on_and_off_produce_identical_tokens() {
    // The cache may only change WHEN work happens, never its result:
    // token streams with the cache on must equal the cache-off streams
    // under both policies, and the on-runs must actually save prefill.
    let off = ThreadedServe::new(|| Engine::load(Artifacts::synthetic(SEED)?))
        .workers(2)
        .policy(Policy::Batched { batch: 4 })
        .run(prefix_heavy_requests())
        .expect("cache-off serve");
    let golden = token_streams(&off);
    for policy in [Policy::Batched { batch: 4 }, Policy::Continuous { max_active: 4 }] {
        let on = ThreadedServe::new(|| prefix_engine(64))
            .workers(2)
            .policy(policy)
            .run(prefix_heavy_requests())
            .expect("cache-on serve");
        assert_eq!(golden, token_streams(&on), "{policy:?} tokens changed");
        let saved: usize = on.iter().map(|r| r.cached_tokens).sum();
        assert!(saved > 0, "{policy:?}: shared system prompts must hit");
    }
}

#[test]
fn prefix_cache_under_preemption_byte_identical_across_runs() {
    // Tight arena + prefix cache + continuous scheduling: admission
    // reclaims index pins, preempts sharers, re-admissions re-share —
    // and the token streams must still be byte-identical every run and
    // equal to the roomy cache-off run.
    let roomy = ThreadedServe::new(|| Engine::load(Artifacts::synthetic(SEED)?))
        .workers(1)
        .policy(Policy::Fifo)
        .run(prefix_heavy_requests())
        .expect("roomy serve");
    let golden = token_streams(&roomy);
    let run = || {
        let engine = prefix_engine(12).unwrap();
        let out = pim_llm::serving::Server::new(&engine, Policy::Continuous { max_active: 8 })
            .serve(prefix_heavy_requests())
            .unwrap();
        engine.debug_validate().unwrap();
        let mut streams = token_streams(&out);
        streams.sort_by_key(|(id, _)| *id);
        streams
    };
    let first = run();
    assert_eq!(golden, first, "tight prefix run diverged from roomy FIFO");
    for r in 1..RUNS {
        assert_eq!(first, run(), "tight prefix run {r} diverged");
    }
}

#[test]
fn tracing_on_produces_byte_identical_tokens() {
    // The observability layer's core contract: instrumentation is
    // inert. The same engine + policy with tracing and metrics ON must
    // produce the same responses IN THE SAME ORDER as with it off —
    // and must actually have recorded something.
    for policy in [Policy::Batched { batch: 3 }, Policy::Continuous { max_active: 3 }] {
        let run = |traced: bool| {
            let engine = Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap();
            if traced {
                engine.obs().set_enabled(true);
            }
            let out = pim_llm::serving::Server::new(&engine, policy)
                .serve(mixed_requests())
                .unwrap();
            let events = engine.obs().trace.drain();
            (token_streams(&out), events.len())
        };
        let (off, none) = run(false);
        let (on, some) = run(true);
        assert_eq!(off, on, "{policy:?}: tracing changed a token");
        assert_eq!(none, 0, "{policy:?}: disabled obs recorded events");
        assert!(some > 0, "{policy:?}: enabled obs recorded nothing");
    }
}

#[test]
fn tracing_on_under_preemption_and_prefix_cache_is_inert() {
    // Tight arena + prefix cache + continuous scheduling is the
    // busiest instrumentation path (preempt events, span rewinds, COW
    // deltas, reclaim/eviction events) — and the most dangerous place
    // for an observer effect. Token streams must not move.
    let run = |traced: bool| {
        let engine = prefix_engine(12).unwrap();
        if traced {
            engine.obs().set_enabled(true);
        }
        let out = pim_llm::serving::Server::new(&engine, Policy::Continuous { max_active: 8 })
            .serve(prefix_heavy_requests())
            .unwrap();
        engine.debug_validate().unwrap();
        let mut streams = token_streams(&out);
        streams.sort_by_key(|(id, _)| *id);
        (streams, engine.obs().trace.drain().len())
    };
    let (off, _) = run(false);
    let (on, events) = run(true);
    assert_eq!(off, on, "tracing changed a token under preemption");
    assert!(events > 0);
}

#[test]
fn degenerate_requests_complete_with_correct_shapes() {
    let out = serve_threaded_with(
        || Engine::load(Artifacts::synthetic(SEED)?),
        mixed_requests(),
        2,
        3,
    )
    .expect("threaded serve");
    let by_id = |id: u64| out.iter().find(|r| r.id == id).expect("response");
    for req in mixed_requests() {
        let r = by_id(req.id);
        assert_eq!(
            r.tokens.len(),
            req.prompt.len() + req.n_new,
            "request {}",
            req.id
        );
        assert_eq!(&r.tokens[..req.prompt.len()], &req.prompt[..]);
    }
    // Zero-work request: completes with no tokens and sane timing.
    let r = by_id(3);
    assert!(r.tokens.is_empty());
    assert!(r.service_s >= 0.0 && r.ttft_s >= 0.0);
}
