//! Property tests for the block-paged KV-cache arena
//! (`runtime::kvcache`): random alloc/grow/free churn must never leak
//! or double-own a block, block tables must only reference live blocks,
//! freed capacity must be fully reusable, and session data must never
//! bleed across sessions. The offline build has no proptest; randomness
//! comes from the in-crate SplitMix64 (`util::rng`) with fixed seeds,
//! so every failure is reproducible.

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{CacheArena, CacheHandle, CacheLayout};
use pim_llm::util::rng::Rng;

fn model(max_ctx: usize) -> ModelInfo {
    ModelInfo {
        vocab: 16,
        d: 8,
        h: 2,
        d_ff: 16,
        n_layers: 2,
        max_ctx,
        eps: 1e-5,
    }
}

#[test]
fn random_churn_never_leaks_or_double_frees() {
    for seed in [1u64, 2, 3, 4, 5] {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_97F4_A7C1));
        let max_ctx = rng.range(8, 40);
        let block_len = rng.range(1, 9);
        let capacity = rng.range(4, 24);
        let layout = CacheLayout::with_block_len(&model(max_ctx), block_len);
        let mut arena = CacheArena::new(layout.clone(), capacity).unwrap();
        let total = arena.status().total_blocks;
        assert_eq!(total, capacity);

        // (handle, highest ensured position) pairs for live sessions,
        // plus a mirror count of blocks each session must hold.
        let mut live: Vec<(CacheHandle, Option<usize>)> = Vec::new();
        let mut freed: Vec<CacheHandle> = Vec::new();
        for op in 0..400 {
            match rng.range(0, 9) {
                // Open a session (always succeeds; blocks come later).
                0 | 1 => {
                    live.push((arena.alloc_session().unwrap(), None));
                }
                // Grow a random live session to a random position.
                2..=5 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range(0, live.len() - 1);
                    let pos = rng.range(0, max_ctx - 1);
                    let (h, ensured) = &mut live[i];
                    let held = arena.session_blocks(*h).unwrap();
                    let result = arena.ensure_capacity(*h, pos);
                    if result.is_ok() {
                        *ensured = Some(ensured.map_or(pos, |e| e.max(pos)));
                    } else {
                        // Only legitimate failure: not enough free
                        // blocks for the FULL need — and the failed call
                        // must have claimed nothing (all-or-nothing).
                        let needed =
                            layout.blocks_for_positions(pos + 1).saturating_sub(held);
                        assert!(
                            arena.status().free_blocks < needed,
                            "seed {seed} op {op}: ensure failed with enough blocks"
                        );
                        assert_eq!(
                            arena.session_blocks(*h).unwrap(),
                            held,
                            "seed {seed} op {op}: failed ensure claimed blocks"
                        );
                    }
                }
                // Free (evict) a random live session.
                6 | 7 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range(0, live.len() - 1);
                    let (h, _) = live.swap_remove(i);
                    arena.free_session(h).unwrap();
                    freed.push(h);
                }
                // Hammer stale handles: every op must error, and error
                // without disturbing the accounting.
                _ => {
                    if let Some(&h) = freed.last() {
                        assert!(arena.free_session(h).is_err());
                        assert!(arena.ensure_capacity(h, 0).is_err());
                        assert!(arena.view(h).is_err());
                        assert!(arena.gather_contiguous(h).is_err());
                    }
                }
            }
            // Invariants after EVERY op.
            arena.debug_validate().unwrap_or_else(|e| {
                panic!("seed {seed} op {op}: arena invariant broken: {e}")
            });
            let st = arena.status();
            assert_eq!(st.total_blocks, total);
            assert_eq!(st.live_sessions, live.len(), "seed {seed} op {op}");
            let held: usize = live
                .iter()
                .map(|(h, _)| arena.session_blocks(*h).unwrap())
                .sum();
            assert_eq!(
                st.free_blocks + held,
                total,
                "seed {seed} op {op}: blocks leaked"
            );
            // Each session holds exactly the blocks its positions need.
            for (h, ensured) in &live {
                let expect = ensured.map_or(0, |e| layout.blocks_for_positions(e + 1));
                assert_eq!(
                    arena.session_blocks(*h).unwrap(),
                    expect,
                    "seed {seed} op {op}: wrong block count"
                );
            }
        }

        // Freeing everything returns the arena to pristine capacity.
        for (h, _) in live.drain(..) {
            arena.free_session(h).unwrap();
        }
        assert_eq!(arena.status().free_blocks, total);
        arena.debug_validate().unwrap();

        // And the full capacity is reusable by one fresh session.
        let h = arena.alloc_session().unwrap();
        let usable = (total * layout.block_len).min(max_ctx);
        arena.ensure_capacity(h, usable - 1).unwrap();
        assert_eq!(
            arena.session_blocks(h).unwrap(),
            layout.blocks_for_positions(usable)
        );
    }
}

#[test]
fn session_data_is_isolated_under_interleaving() {
    // Two sessions written with distinguishable patterns in interleaved
    // order, with a third churning alloc/free in between: each gather
    // must return exactly its own writes.
    let layout = CacheLayout::with_block_len(&model(12), 3);
    let mut arena = CacheArena::new(layout.clone(), 12).unwrap();
    let a = arena.alloc_session().unwrap();
    let b = arena.alloc_session().unwrap();
    let row = |tag: usize, layer: usize, pos: usize| -> Vec<f32> {
        (0..layout.h * layout.dh)
            .map(|i| (tag * 10000 + layer * 1000 + pos * 10 + i) as f32)
            .collect()
    };
    for pos in 0..12usize {
        // Churn: a short-lived session claims and releases blocks.
        let tmp = arena.alloc_session().unwrap();
        arena.ensure_capacity(tmp, pos.min(5)).unwrap();
        for (tag, h) in [(1usize, a), (2usize, b)] {
            arena.ensure_capacity(h, pos).unwrap();
            for layer in 0..layout.n_layers {
                let r = row(tag, layer, pos);
                let neg: Vec<f32> = r.iter().map(|x| -x).collect();
                arena.write_kv(h, layer, pos, &r, &neg).unwrap();
            }
        }
        arena.free_session(tmp).unwrap();
    }
    for (tag, h) in [(1usize, a), (2usize, b)] {
        let (k, v) = arena.gather_contiguous(h).unwrap();
        for layer in 0..layout.n_layers {
            for pos in 0..12usize {
                let r = row(tag, layer, pos);
                for head in 0..layout.h {
                    let base = ((layer * layout.h + head) * layout.max_ctx + pos) * layout.dh;
                    let want = &r[head * layout.dh..(head + 1) * layout.dh];
                    assert_eq!(&k[base..base + layout.dh], want, "session {tag} K");
                    let neg: Vec<f32> = want.iter().map(|x| -x).collect();
                    assert_eq!(&v[base..base + layout.dh], &neg[..], "session {tag} V");
                }
            }
        }
    }
    arena.debug_validate().unwrap();
}

#[test]
fn exhaustion_is_an_error_not_a_corruption() {
    // Drive the pool to empty, verify the error, free one session, and
    // confirm the freed capacity is immediately usable by another.
    let layout = CacheLayout::with_block_len(&model(16), 2);
    let mut arena = CacheArena::new(layout, 4).unwrap();
    let a = arena.alloc_session().unwrap();
    let b = arena.alloc_session().unwrap();
    arena.ensure_capacity(a, 3).unwrap(); // 2 blocks
    arena.ensure_capacity(b, 3).unwrap(); // 2 blocks
    assert_eq!(arena.status().free_blocks, 0);
    let err = arena.ensure_capacity(a, 5).unwrap_err();
    assert!(
        format!("{err}").contains("out of blocks"),
        "unexpected error: {err}"
    );
    // Partial-failure safety: a's table is unchanged (2 blocks).
    assert_eq!(arena.session_blocks(a).unwrap(), 2);
    arena.debug_validate().unwrap();
    arena.free_session(b).unwrap();
    arena.ensure_capacity(a, 5).unwrap();
    assert_eq!(arena.session_blocks(a).unwrap(), 3);
    arena.debug_validate().unwrap();
}

#[test]
fn handle_reuse_changes_identity() {
    // Slot reuse after free must produce handles that do not validate
    // for the old session (generation bump), across many cycles.
    let layout = CacheLayout::with_block_len(&model(8), 4);
    let mut arena = CacheArena::new(layout, 2).unwrap();
    let mut old: Vec<CacheHandle> = Vec::new();
    for cycle in 0..50 {
        let h = arena.alloc_session().unwrap();
        arena.ensure_capacity(h, 0).unwrap();
        for &stale in &old {
            assert!(arena.view(stale).is_err(), "cycle {cycle}: stale validated");
            assert_ne!(stale.key(), h.key(), "cycle {cycle}: key collision");
        }
        arena.free_session(h).unwrap();
        old.push(h);
    }
}
