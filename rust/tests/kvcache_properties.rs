//! Property tests for the block-paged KV-cache arena
//! (`runtime::kvcache`): random alloc/grow/free churn must never leak
//! or double-own a block, block tables must only reference live blocks,
//! freed capacity must be fully reusable, and session data must never
//! bleed across sessions. Since the copy-on-write prefix cache, blocks
//! are REFCOUNTED (table occurrences + prefix-index pins), so the churn
//! also hammers share/cow/pin/unpin sequences: a block may only reach
//! the free list at refcount zero, never twice, and `debug_validate`
//! must balance the refcount equation after every operation. The
//! offline build has no proptest; randomness comes from the in-crate
//! SplitMix64 (`util::rng`) with fixed seeds, so every failure is
//! reproducible.

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{ArenaLayout, CacheArena, CacheHandle, CacheLayout};
use pim_llm::util::rng::Rng;

/// Both storage layouts: the refcount/free-list machinery is
/// layout-blind, so every structural property must hold identically
/// over the int8 pools (which add per-group scale metadata to the
/// blocks being claimed, shared, COW'd, and recycled).
const MODES: [ArenaLayout; 2] = [ArenaLayout::F32, ArenaLayout::KvInt8];

fn model(max_ctx: usize) -> ModelInfo {
    ModelInfo {
        vocab: 16,
        d: 8,
        h: 2,
        d_ff: 16,
        n_layers: 2,
        max_ctx,
        eps: 1e-5,
    }
}

#[test]
fn random_churn_never_leaks_or_double_frees() {
    for (mode, seed) in MODES
        .into_iter()
        .flat_map(|m| [1u64, 2, 3, 4, 5].map(|s| (m, s)))
    {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_97F4_A7C1));
        let max_ctx = rng.range(8, 40);
        let block_len = rng.range(1, 9);
        let capacity = rng.range(4, 24);
        let layout = CacheLayout::with_block_len(&model(max_ctx), block_len);
        let mut arena = CacheArena::new_with_mode(layout.clone(), capacity, mode).unwrap();
        let total = arena.status().total_blocks;
        assert_eq!(total, capacity);

        // (handle, highest ensured position) pairs for live sessions,
        // plus a mirror count of blocks each session must hold.
        let mut live: Vec<(CacheHandle, Option<usize>)> = Vec::new();
        let mut freed: Vec<CacheHandle> = Vec::new();
        for op in 0..400 {
            match rng.range(0, 9) {
                // Open a session (always succeeds; blocks come later).
                0 | 1 => {
                    live.push((arena.alloc_session().unwrap(), None));
                }
                // Grow a random live session to a random position.
                2..=5 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range(0, live.len() - 1);
                    let pos = rng.range(0, max_ctx - 1);
                    let (h, ensured) = &mut live[i];
                    let held = arena.session_blocks(*h).unwrap();
                    let result = arena.ensure_capacity(*h, pos);
                    if result.is_ok() {
                        *ensured = Some(ensured.map_or(pos, |e| e.max(pos)));
                    } else {
                        // Only legitimate failure: not enough free
                        // blocks for the FULL need — and the failed call
                        // must have claimed nothing (all-or-nothing).
                        let needed =
                            layout.blocks_for_positions(pos + 1).saturating_sub(held);
                        assert!(
                            arena.status().free_blocks < needed,
                            "seed {seed} op {op}: ensure failed with enough blocks"
                        );
                        assert_eq!(
                            arena.session_blocks(*h).unwrap(),
                            held,
                            "seed {seed} op {op}: failed ensure claimed blocks"
                        );
                    }
                }
                // Free (evict) a random live session.
                6 | 7 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range(0, live.len() - 1);
                    let (h, _) = live.swap_remove(i);
                    arena.free_session(h).unwrap();
                    freed.push(h);
                }
                // Hammer stale handles: every op must error, and error
                // without disturbing the accounting.
                _ => {
                    if let Some(&h) = freed.last() {
                        assert!(arena.free_session(h).is_err());
                        assert!(arena.ensure_capacity(h, 0).is_err());
                        assert!(arena.view(h).is_err());
                        assert!(arena.gather_contiguous(h).is_err());
                    }
                }
            }
            // Invariants after EVERY op.
            arena.debug_validate().unwrap_or_else(|e| {
                panic!("seed {seed} op {op}: arena invariant broken: {e}")
            });
            let st = arena.status();
            assert_eq!(st.total_blocks, total);
            assert_eq!(st.live_sessions, live.len(), "seed {seed} op {op}");
            let held: usize = live
                .iter()
                .map(|(h, _)| arena.session_blocks(*h).unwrap())
                .sum();
            assert_eq!(
                st.free_blocks + held,
                total,
                "seed {seed} op {op}: blocks leaked"
            );
            // Each session holds exactly the blocks its positions need.
            for (h, ensured) in &live {
                let expect = ensured.map_or(0, |e| layout.blocks_for_positions(e + 1));
                assert_eq!(
                    arena.session_blocks(*h).unwrap(),
                    expect,
                    "seed {seed} op {op}: wrong block count"
                );
            }
        }

        // Freeing everything returns the arena to pristine capacity.
        for (h, _) in live.drain(..) {
            arena.free_session(h).unwrap();
        }
        assert_eq!(arena.status().free_blocks, total);
        arena.debug_validate().unwrap();

        // And the full capacity is reusable by one fresh session.
        let h = arena.alloc_session().unwrap();
        let usable = (total * layout.block_len).min(max_ctx);
        arena.ensure_capacity(h, usable - 1).unwrap();
        assert_eq!(
            arena.session_blocks(h).unwrap(),
            layout.blocks_for_positions(usable)
        );
    }
}

#[test]
fn refcounted_share_cow_pin_churn_never_leaks_or_double_frees() {
    // Randomized share/cow/free/pin/unpin sequences across 5 seeds. An
    // external mirror tracks the pin multiset and which (session,
    // block) shares exist; after EVERY op the arena must validate
    // (refcount == table occurrences + pins, free exactly at zero) and
    // the free count must match the mirror's conservation equation.
    for (mode, seed) in MODES
        .into_iter()
        .flat_map(|m| [11u64, 12, 13, 14, 15].map(|s| (m, s)))
    {
        let mut rng = Rng::new(seed.wrapping_mul(0xB5E5_5E5B_0F0F_F0F0));
        let max_ctx = rng.range(12, 40);
        let block_len = rng.range(1, 6);
        let capacity = rng.range(6, 24);
        let layout = CacheLayout::with_block_len(&model(max_ctx), block_len);
        let mut arena = CacheArena::new_with_mode(layout.clone(), capacity, mode).unwrap();
        let total = arena.status().total_blocks;

        let mut live: Vec<CacheHandle> = Vec::new();
        let mut freed: Vec<CacheHandle> = Vec::new();
        // Mirror of every pin issued (block ids, with multiplicity).
        let mut pins: Vec<u32> = Vec::new();
        for op in 0..500 {
            match rng.range(0, 11) {
                0 | 1 => {
                    live.push(arena.alloc_session().unwrap());
                }
                2 | 3 => {
                    // Grow a random session (may COW a shared block —
                    // ensure_capacity handles both).
                    if live.is_empty() {
                        continue;
                    }
                    let h = live[rng.range(0, live.len() - 1)];
                    let _ = arena.ensure_capacity(h, rng.range(0, max_ctx - 1));
                }
                4 | 5 => {
                    // Share a random prefix of one session's table into
                    // a FRESH session (the adoption shape).
                    if live.is_empty() {
                        continue;
                    }
                    let donor = live[rng.range(0, live.len() - 1)];
                    let table = arena.session_table(donor).unwrap();
                    if table.is_empty() {
                        continue;
                    }
                    let n = rng.range(1, table.len());
                    let s = arena.alloc_session().unwrap();
                    arena.share_blocks(s, &table[..n]).unwrap();
                    live.push(s);
                }
                6 => {
                    // COW a random table entry with a random keep count.
                    if live.is_empty() {
                        continue;
                    }
                    let h = live[rng.range(0, live.len() - 1)];
                    let held = arena.session_blocks(h).unwrap();
                    if held == 0 {
                        continue;
                    }
                    let _ = arena.cow_block(
                        h,
                        rng.range(0, held - 1),
                        rng.range(0, block_len),
                    );
                }
                7 => {
                    // Pin a random live block (what the prefix index
                    // does at insert).
                    if live.is_empty() {
                        continue;
                    }
                    let h = live[rng.range(0, live.len() - 1)];
                    let table = arena.session_table(h).unwrap();
                    if table.is_empty() {
                        continue;
                    }
                    let b = table[rng.range(0, table.len() - 1)];
                    arena.pin_block(b).unwrap();
                    pins.push(b);
                }
                8 => {
                    // Unpin (LRU eviction / reclaim).
                    if pins.is_empty() {
                        continue;
                    }
                    let b = pins.swap_remove(rng.range(0, pins.len() - 1));
                    arena.unpin_block(b).unwrap();
                }
                9 => {
                    // Free a random session; shared blocks must survive.
                    if live.is_empty() {
                        continue;
                    }
                    let h = live.swap_remove(rng.range(0, live.len() - 1));
                    arena.free_session(h).unwrap();
                    freed.push(h);
                }
                _ => {
                    // Stale handles: every op — including the sharing
                    // ops — must error without touching the accounting.
                    if let Some(&h) = freed.last() {
                        assert!(arena.free_session(h).is_err());
                        assert!(arena.share_blocks(h, &[0]).is_err());
                        assert!(arena.cow_block(h, 0, 0).is_err());
                        assert!(arena.session_table(h).is_err());
                    }
                }
            }
            arena.debug_validate().unwrap_or_else(|e| {
                panic!("seed {seed} op {op}: arena invariant broken: {e}")
            });
            let st = arena.status();
            assert_eq!(st.total_blocks, total, "seed {seed} op {op}");
            assert_eq!(st.free_blocks + st.used_blocks, total, "seed {seed} op {op}");
            assert_eq!(st.live_sessions, live.len(), "seed {seed} op {op}");
            // Conservation from the mirror: every block referenced by a
            // live table or a pin is used; everything else is free.
            let mut used = vec![false; total];
            for &h in &live {
                for b in arena.session_table(h).unwrap() {
                    used[b as usize] = true;
                }
            }
            for &b in &pins {
                used[b as usize] = true;
            }
            let expect_used = used.iter().filter(|&&u| u).count();
            assert_eq!(
                st.used_blocks, expect_used,
                "seed {seed} op {op}: used-block mirror diverged"
            );
            // Free only at refcount zero: no pinned or table-held block
            // may have refcount 0.
            for (b, &u) in used.iter().enumerate() {
                if u {
                    assert!(
                        arena.block_refs(b as u32) > 0,
                        "seed {seed} op {op}: referenced block {b} has refcount 0"
                    );
                }
            }
        }

        // Drain: free every session and pin; the arena must return to
        // pristine capacity with no block lost or freed twice.
        for h in live.drain(..) {
            arena.free_session(h).unwrap();
        }
        for b in pins.drain(..) {
            arena.unpin_block(b).unwrap();
        }
        assert_eq!(arena.status().free_blocks, total, "seed {seed}: leak at drain");
        arena.debug_validate().unwrap();
    }
}

#[test]
fn preempted_sharer_never_returns_referenced_blocks_to_free_list() {
    // The eviction regression (CacheArena::free_session used to assume
    // exclusive ownership): free a session that shares blocks with a
    // pinned prefix chain and a sibling session, and verify — by
    // claiming every remaining free block — that no shared block was
    // handed out again while still referenced.
    let layout = CacheLayout::with_block_len(&model(16), 2);
    let mut arena = CacheArena::new(layout, 8).unwrap();
    let donor = arena.alloc_session().unwrap();
    arena.ensure_capacity(donor, 5).unwrap(); // 3 blocks
    let chain = arena.session_table(donor).unwrap();
    for &b in &chain[..2] {
        arena.pin_block(b).unwrap(); // "prefix index" pins 2 of them
    }
    let sharer = arena.alloc_session().unwrap();
    arena.share_blocks(sharer, &chain).unwrap();
    // Preempt the sharer: only its references drop; nothing frees.
    let free_before = arena.status().free_blocks;
    arena.free_session(sharer).unwrap();
    assert_eq!(arena.status().free_blocks, free_before);
    // Preempt the donor too: block 2 (unpinned, now unreferenced) is
    // the ONLY one that may come back.
    arena.free_session(donor).unwrap();
    assert_eq!(arena.status().free_blocks, free_before + 1);
    // Exhaust the free list: none of the handed-out blocks may be a
    // still-pinned chain block.
    let grabber = arena.alloc_session().unwrap();
    let usable = arena.status().free_blocks * 2; // block_len = 2
    arena.ensure_capacity(grabber, usable - 1).unwrap();
    assert_eq!(arena.status().free_blocks, 0);
    for b in arena.session_table(grabber).unwrap() {
        assert!(
            !chain[..2].contains(&b),
            "still-pinned block {b} reached the free list"
        );
    }
    arena.debug_validate().unwrap();
}

#[test]
fn session_data_is_isolated_under_interleaving() {
    // Two sessions written with distinguishable patterns in interleaved
    // order, with a third churning alloc/free in between: each gather
    // must return exactly its own writes.
    let layout = CacheLayout::with_block_len(&model(12), 3);
    let mut arena = CacheArena::new(layout.clone(), 12).unwrap();
    let a = arena.alloc_session().unwrap();
    let b = arena.alloc_session().unwrap();
    let row = |tag: usize, layer: usize, pos: usize| -> Vec<f32> {
        (0..layout.h * layout.dh)
            .map(|i| (tag * 10000 + layer * 1000 + pos * 10 + i) as f32)
            .collect()
    };
    for pos in 0..12usize {
        // Churn: a short-lived session claims and releases blocks.
        let tmp = arena.alloc_session().unwrap();
        arena.ensure_capacity(tmp, pos.min(5)).unwrap();
        for (tag, h) in [(1usize, a), (2usize, b)] {
            arena.ensure_capacity(h, pos).unwrap();
            for layer in 0..layout.n_layers {
                let r = row(tag, layer, pos);
                let neg: Vec<f32> = r.iter().map(|x| -x).collect();
                arena.write_kv(h, layer, pos, &r, &neg).unwrap();
            }
        }
        arena.free_session(tmp).unwrap();
    }
    for (tag, h) in [(1usize, a), (2usize, b)] {
        let (k, v) = arena.gather_contiguous(h).unwrap();
        for layer in 0..layout.n_layers {
            for pos in 0..12usize {
                let r = row(tag, layer, pos);
                for head in 0..layout.h {
                    let base = ((layer * layout.h + head) * layout.max_ctx + pos) * layout.dh;
                    let want = &r[head * layout.dh..(head + 1) * layout.dh];
                    assert_eq!(&k[base..base + layout.dh], want, "session {tag} K");
                    let neg: Vec<f32> = want.iter().map(|x| -x).collect();
                    assert_eq!(&v[base..base + layout.dh], &neg[..], "session {tag} V");
                }
            }
        }
    }
    arena.debug_validate().unwrap();
}

#[test]
fn exhaustion_is_an_error_not_a_corruption() {
    // Drive the pool to empty, verify the error, free one session, and
    // confirm the freed capacity is immediately usable by another.
    let layout = CacheLayout::with_block_len(&model(16), 2);
    let mut arena = CacheArena::new(layout, 4).unwrap();
    let a = arena.alloc_session().unwrap();
    let b = arena.alloc_session().unwrap();
    arena.ensure_capacity(a, 3).unwrap(); // 2 blocks
    arena.ensure_capacity(b, 3).unwrap(); // 2 blocks
    assert_eq!(arena.status().free_blocks, 0);
    let err = arena.ensure_capacity(a, 5).unwrap_err();
    assert!(
        format!("{err}").contains("out of blocks"),
        "unexpected error: {err}"
    );
    // Partial-failure safety: a's table is unchanged (2 blocks).
    assert_eq!(arena.session_blocks(a).unwrap(), 2);
    arena.debug_validate().unwrap();
    arena.free_session(b).unwrap();
    arena.ensure_capacity(a, 5).unwrap();
    assert_eq!(arena.session_blocks(a).unwrap(), 3);
    arena.debug_validate().unwrap();
}

#[test]
fn cow_kept_rows_read_back_identically_in_both_layouts() {
    // Randomized COW byte preservation: whatever rows the adopter keeps
    // must read back EXACTLY as the donor's — in int8 that means the
    // copy carried the group scales along with the codes (copying codes
    // under a fresh scale would silently rescale the kept rows) — and
    // the tail of the copied block must read as zero. Everything
    // outside the copied block stays shared and therefore identical.
    for (mode, seed) in MODES
        .into_iter()
        .flat_map(|m| [21u64, 22, 23].map(|s| (m, s)))
    {
        let mut rng = Rng::new(seed.wrapping_mul(0xC01D_C0FF_EE15_F00D));
        let max_ctx = rng.range(12, 24);
        let block_len = rng.range(2, 6);
        let layout = CacheLayout::with_block_len(&model(max_ctx), block_len);
        let mut arena = CacheArena::new_with_mode(layout.clone(), 24, mode).unwrap();
        let donor = arena.alloc_session().unwrap();
        let filled = rng.range(layout.block_len + 1, max_ctx - 1);
        for pos in 0..filled {
            arena.ensure_capacity(donor, pos).unwrap();
            for layer in 0..layout.n_layers {
                let k: Vec<f32> =
                    (0..layout.h * layout.dh).map(|_| rng.normal() as f32).collect();
                let v: Vec<f32> =
                    (0..layout.h * layout.dh).map(|_| rng.normal() as f32).collect();
                arena.write_kv(donor, layer, pos, &k, &v).unwrap();
            }
        }
        let (dk, dv) = arena.gather_contiguous(donor).unwrap();
        let chain = arena.session_table(donor).unwrap();
        let s = arena.alloc_session().unwrap();
        arena.share_blocks(s, &chain).unwrap();
        let cow_at = rng.range(0, chain.len() - 1);
        let keep = rng.range(0, layout.block_len);
        assert!(
            arena.cow_block(s, cow_at, keep).unwrap(),
            "seed {seed} {mode:?}: shared block must actually copy"
        );
        let (sk, sv) = arena.gather_contiguous(s).unwrap();
        let copy_lo = cow_at * layout.block_len;
        let copy_hi = ((cow_at + 1) * layout.block_len).min(layout.max_ctx);
        for layer in 0..layout.n_layers {
            for head in 0..layout.h {
                for pos in 0..layout.max_ctx {
                    let at = ((layer * layout.h + head) * layout.max_ctx + pos) * layout.dh;
                    let zero_tail = pos >= copy_lo + keep && pos < copy_hi;
                    for j in 0..layout.dh {
                        let (wk, wv) = if zero_tail {
                            (0.0, 0.0)
                        } else {
                            (dk[at + j], dv[at + j])
                        };
                        assert_eq!(
                            sk[at + j], wk,
                            "seed {seed} {mode:?} K layer {layer} head {head} pos {pos} \
                             (cow block {cow_at}, keep {keep})"
                        );
                        assert_eq!(
                            sv[at + j], wv,
                            "seed {seed} {mode:?} V layer {layer} head {head} pos {pos} \
                             (cow block {cow_at}, keep {keep})"
                        );
                    }
                }
            }
        }
        // And the donor read back unchanged — the COW never writes into
        // shared storage.
        assert_eq!(arena.gather_contiguous(donor).unwrap(), (dk, dv), "seed {seed} {mode:?}");
        arena.debug_validate().unwrap();
        let total = arena.status().total_blocks;
        arena.free_session(s).unwrap();
        arena.free_session(donor).unwrap();
        assert_eq!(arena.status().free_blocks, total, "seed {seed} {mode:?}: leak");
        arena.debug_validate().unwrap();
    }
}

#[test]
fn handle_reuse_changes_identity() {
    // Slot reuse after free must produce handles that do not validate
    // for the old session (generation bump), across many cycles.
    let layout = CacheLayout::with_block_len(&model(8), 4);
    let mut arena = CacheArena::new(layout, 2).unwrap();
    let mut old: Vec<CacheHandle> = Vec::new();
    for cycle in 0..50 {
        let h = arena.alloc_session().unwrap();
        arena.ensure_capacity(h, 0).unwrap();
        for &stale in &old {
            assert!(arena.view(stale).is_err(), "cycle {cycle}: stale validated");
            assert_ne!(stale.key(), h.key(), "cycle {cycle}: key collision");
        }
        arena.free_session(h).unwrap();
        old.push(h);
    }
}
