//! Determinism suite for the sharded multi-worker serving engine: the
//! headline guarantee of `serving::serve_sharded` is that every
//! response is BYTE-IDENTICAL across `workers ∈ {1, 2, 4, 8}` and
//! across repeated runs — worker count, hash placement, and steal
//! timing may change which shard decodes a request, never what it
//! decodes. The suite pins that guarantee on a mixed ragged workload
//! (empty prompts, zero-generation requests) under both host backends,
//! with the prefix cache on and off, and under tight-arena preemption
//! churn; a final property test hammers `CacheArena::split` shards
//! with 500 random alloc/grow/free/steal ops, validating every shard's
//! refcount accounting after every operation.

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::{
    Artifacts, BackendKind, CacheArena, CacheHandle, CacheLayout, Engine, ShardedEngine,
};
use pim_llm::serving::{serve_sharded_stats, Policy, Request, Response, Server};
use pim_llm::util::rng::Rng;

const SEED: u64 = 0x5AAD;
const RUNS: usize = 5;

/// Ragged request mix with degenerate shapes — ids chosen densely so
/// the placement hash actually spreads them across up to 8 shards.
fn mixed_requests() -> Vec<Request> {
    let mut reqs = vec![
        Request { id: 0, prompt: vec![1, 2, 3, 4, 5, 6], n_new: 5 },
        Request { id: 1, prompt: vec![], n_new: 4 },
        Request { id: 2, prompt: vec![7], n_new: 0 },
        Request { id: 3, prompt: vec![], n_new: 0 },
        Request { id: 4, prompt: vec![9, 8, 7], n_new: 7 },
        Request { id: 5, prompt: vec![2; 10], n_new: 1 },
        Request { id: 6, prompt: vec![5, 5], n_new: 6 },
        Request { id: 7, prompt: vec![63, 1], n_new: 3 },
    ];
    for id in 8..20u64 {
        reqs.push(Request {
            id,
            prompt: (0..(id % 5) as i32 + 1).map(|i| (id as i32 * 3 + i) % 60 + 1).collect(),
            n_new: (id % 6) as usize + 1,
        });
    }
    reqs
}

/// Prefix-heavy mix: many requests, two distinct 8-token system
/// prompts, ragged suffixes — the copy-on-write prefix cache's shape.
fn prefix_requests() -> Vec<Request> {
    let systems: [Vec<i32>; 2] = [
        vec![31, 7, 19, 2, 44, 5, 23, 11],
        vec![8, 8, 60, 1, 12, 39, 4, 27],
    ];
    (0..16u64)
        .map(|id| {
            let mut prompt = systems[(id % 2) as usize].clone();
            for j in 0..(id % 3) {
                prompt.push((id * 5 + j + 1) as i32);
            }
            Request {
                id,
                prompt,
                n_new: (id % 4) as usize + 1,
            }
        })
        .collect()
}

/// The byte-comparable part of a response set, sorted by id.
fn token_streams(responses: &[Response]) -> Vec<(u64, Vec<i32>)> {
    let mut v: Vec<(u64, Vec<i32>)> = responses
        .iter()
        .map(|r| (r.id, r.tokens.clone()))
        .collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

/// Single-engine FIFO on a roomy arena — the oracle every sharded
/// configuration must match byte-for-byte.
fn golden(requests: Vec<Request>) -> Vec<(u64, Vec<i32>)> {
    let engine = Engine::load(Artifacts::synthetic(SEED).unwrap()).unwrap();
    let out = Server::new(&engine, Policy::Fifo).serve(requests).unwrap();
    token_streams(&out)
}

/// One sharded run: `workers` shards over `total_blocks` TOTAL arena
/// blocks (block length 4), `max_active` lanes per worker, prefix cache
/// on request. Returns the sorted token streams after validating shard
/// accounting and that nothing leaked.
fn sharded_run(
    kind: BackendKind,
    requests: Vec<Request>,
    workers: usize,
    total_blocks: usize,
    max_active: usize,
    prefix: bool,
) -> Vec<(u64, Vec<i32>)> {
    let n = requests.len();
    let mut engine = ShardedEngine::load(
        Artifacts::synthetic(SEED).unwrap(),
        kind,
        4,
        total_blocks,
        workers,
    )
    .unwrap();
    if prefix {
        assert!(engine.enable_prefix_cache(0));
    }
    let offsets = vec![0.0; n];
    let (out, stats) =
        serve_sharded_stats(&mut engine, requests, &offsets, max_active).unwrap();
    // Exactly-once: every request placed on one shard and served once.
    assert_eq!(stats.iter().map(|s| s.placed).sum::<usize>(), n);
    assert_eq!(stats.iter().map(|s| s.served).sum::<usize>(), n);
    // Per-shard refcount accounting holds and no block leaked.
    engine.debug_validate().unwrap();
    let st = engine.arena_status();
    assert_eq!(
        st.free_blocks, st.total_blocks,
        "{workers}-worker run leaked blocks"
    );
    token_streams(&out)
}

#[test]
fn byte_identical_across_worker_counts_reference() {
    let oracle = golden(mixed_requests());
    for workers in [1usize, 2, 4, 8] {
        // Equal TOTAL capacity at every worker count: 64 blocks.
        let streams = sharded_run(
            BackendKind::Reference,
            mixed_requests(),
            workers,
            64,
            2,
            false,
        );
        assert_eq!(oracle, streams, "{workers} workers diverged (reference)");
    }
}

#[test]
fn byte_identical_across_worker_counts_packed() {
    let oracle = golden(mixed_requests());
    for workers in [1usize, 2, 4, 8] {
        let streams = sharded_run(
            BackendKind::Packed,
            mixed_requests(),
            workers,
            64,
            2,
            false,
        );
        assert_eq!(oracle, streams, "{workers} workers diverged (packed)");
    }
}

#[test]
fn byte_identical_across_repeated_runs() {
    // Steal timing varies run to run (it races on wall clock); the
    // tokens must not.
    let first = sharded_run(BackendKind::Reference, mixed_requests(), 4, 64, 2, false);
    for run in 1..RUNS {
        let again = sharded_run(BackendKind::Reference, mixed_requests(), 4, 64, 2, false);
        assert_eq!(first, again, "4-worker run {run} diverged");
    }
}

#[test]
fn prefix_cache_on_changes_no_token_across_worker_counts() {
    // Shard-local prefix indices: requests sharing a system prompt only
    // share blocks when they land on the SAME shard, and stolen
    // requests re-prefill on the thief's shard — either way the tokens
    // must equal the cache-off oracle at every worker count.
    let oracle = golden(prefix_requests());
    for workers in [1usize, 2, 4, 8] {
        for prefix in [false, true] {
            let streams = sharded_run(
                BackendKind::Reference,
                prefix_requests(),
                workers,
                64,
                2,
                prefix,
            );
            assert_eq!(
                oracle, streams,
                "{workers} workers prefix={prefix} diverged"
            );
        }
    }
}

#[test]
fn tight_arena_preemption_byte_identical() {
    // 6 blocks per shard and 4 lanes per worker: admission defers,
    // pressure preempts, preempted requests re-prefill — on every
    // shard independently. Tokens must still equal the roomy oracle,
    // every worker count, every repetition.
    let oracle = golden(mixed_requests());
    for workers in [1usize, 2, 4] {
        for run in 0..2 {
            let streams = sharded_run(
                BackendKind::Reference,
                mixed_requests(),
                workers,
                6 * workers,
                4,
                false,
            );
            assert_eq!(
                oracle, streams,
                "tight arena x{workers} run {run} diverged"
            );
        }
    }
}

#[test]
fn tracing_on_is_byte_inert_across_worker_counts() {
    // The observability contract on the sharded path: per-shard trace
    // rings and metrics registries must not move a single token, at any
    // worker count, even under tight-arena preemption churn — and every
    // enabled run must actually record events on at least one shard.
    let oracle = golden(mixed_requests());
    for workers in [1usize, 2, 4] {
        let n = mixed_requests().len();
        let mut engine = ShardedEngine::load(
            Artifacts::synthetic(SEED).unwrap(),
            BackendKind::Reference,
            4,
            6 * workers,
            workers,
        )
        .unwrap();
        engine.set_obs_enabled(true);
        let offsets = vec![0.0; n];
        let (out, stats) = pim_llm::serving::serve_sharded_stats_opts(
            &mut engine,
            mixed_requests(),
            &offsets,
            4,
            2,
        )
        .unwrap();
        assert_eq!(stats.iter().map(|s| s.served).sum::<usize>(), n);
        engine.debug_validate().unwrap();
        assert_eq!(
            oracle,
            token_streams(&out),
            "{workers} workers: tracing changed a token"
        );
        let total: usize = engine.drain_traces().iter().map(|(_, e)| e.len()).sum();
        assert!(total > 0, "{workers} workers: no events recorded");
        let snap = engine.metrics_snapshot();
        assert_eq!(
            snap.counter(pim_llm::obs::Counter::Retired),
            n as u64,
            "{workers} workers: retire accounting diverged"
        );
    }
}

// ---------------------------------------------------------------------
// Property test: shard arenas under random churn with steals.
// ---------------------------------------------------------------------

fn model(max_ctx: usize) -> ModelInfo {
    ModelInfo {
        vocab: 16,
        d: 8,
        h: 2,
        d_ff: 16,
        n_layers: 2,
        max_ctx,
        eps: 1e-5,
    }
}

#[test]
fn split_shards_survive_500_op_churn_with_steals() {
    // Shards from one `CacheArena::split` are fully independent arenas:
    // random per-shard alloc/grow/free plus "steals" (a session freed on
    // its home shard and re-begun from scratch on another — exactly what
    // serving's work stealing does to a preempted-or-queued request)
    // must keep every shard's refcount equation balanced after EVERY op,
    // and a full drain must return every shard to all-free.
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_97F4_A7C1));
        let max_ctx = 24;
        let layout = CacheLayout::with_block_len(&model(max_ctx), 4);
        let shards = 4usize;
        let mut arenas = CacheArena::split(layout, 26, shards).unwrap();
        // Live session registry: (shard, handle).
        let mut live: Vec<(usize, CacheHandle)> = Vec::new();
        for _op in 0..500 {
            match rng.range(0, 7) {
                // Open a session on a random shard.
                0 | 1 => {
                    let s = rng.range(0, shards - 1);
                    live.push((s, arenas[s].alloc_session().unwrap()));
                }
                // Grow a random session on ITS OWN shard (block ids are
                // shard-local; a handle is meaningless elsewhere).
                2 | 3 | 4 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range(0, live.len() - 1);
                    let (s, h) = live[i];
                    let pos = rng.range(0, max_ctx - 1);
                    let need = arenas[s].layout().blocks_for_positions(pos + 1);
                    let held = arenas[s].session_blocks(h).unwrap();
                    let free = arenas[s].status().free_blocks;
                    if need.saturating_sub(held) <= free {
                        arenas[s].ensure_capacity(h, pos).unwrap();
                    } else {
                        // Shard full: per-shard pressure. Retire the
                        // session instead (serving would preempt here).
                        arenas[s].free_session(h).unwrap();
                        live.swap_remove(i);
                    }
                }
                // Retire a random session.
                5 => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range(0, live.len() - 1);
                    let (s, h) = live.swap_remove(i);
                    arenas[s].free_session(h).unwrap();
                }
                // Steal: move a session's REQUEST to another shard —
                // free it at home, restart it from nothing on the
                // thief (no block, table entry, or refcount crosses
                // the boundary; the thief re-prefills).
                _ => {
                    if live.is_empty() {
                        continue;
                    }
                    let i = rng.range(0, live.len() - 1);
                    let (victim, h) = live.swap_remove(i);
                    arenas[victim].free_session(h).unwrap();
                    let thief = (victim + rng.range(1, shards - 1)) % shards;
                    let nh = arenas[thief].alloc_session().unwrap();
                    let pos = rng.range(0, 7);
                    let need = arenas[thief].layout().blocks_for_positions(pos + 1);
                    if need <= arenas[thief].status().free_blocks {
                        arenas[thief].ensure_capacity(nh, pos).unwrap();
                    }
                    live.push((thief, nh));
                }
            }
            // Every shard's accounting must balance after every op,
            // and the shard totals must stay disjoint and constant.
            let mut total = 0;
            for (s, a) in arenas.iter().enumerate() {
                a.debug_validate()
                    .unwrap_or_else(|e| panic!("shard {s} seed {seed}: {e}"));
                total += a.status().total_blocks;
            }
            assert_eq!(total, 26);
        }
        // Drain: every shard returns to fully free.
        for (s, h) in live.drain(..) {
            arenas[s].free_session(h).unwrap();
        }
        for a in &arenas {
            let st = a.status();
            assert_eq!(st.free_blocks, st.total_blocks);
            assert_eq!(st.live_sessions, 0);
            a.debug_validate().unwrap();
        }
    }
}
