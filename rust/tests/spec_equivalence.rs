//! The speculative-decoding pin: greedy-exact drafting is a THROUGHPUT
//! knob, never an accuracy knob. Every combination of draft source
//! (self / tiny synthetic / oracle replay), span width `k`, host
//! backend, scheduling policy, worker count, chunked prefill, prefix
//! cache, preemption pressure and KV quantization must serve tokens
//! BIT-FOR-BIT identical to the spec-off run.
//!
//! Why exactness holds: the target verifies every drafted position with
//! its own logits before the position can influence output — the first
//! unverified token is exactly `greedy_argmax` of the last VERIFIED
//! logits (the classic next token), accepted positions extend the
//! greedy chain by construction, and rejected draft KV rows are rolled
//! back through the arena block table (`truncate_session`) before any
//! later read. On int8 arenas the engine never writes unverified rows
//! at all (sequential verify-then-commit), so lossy requantization sees
//! the same write sequence either way.

use pim_llm::runtime::{
    ArenaLayout, Artifacts, BackendKind, Engine, ShardedEngine, SpecPlan,
};
use pim_llm::serving::{serve_sharded_stats_lanes, Policy, Request, Response, Server};
use std::collections::HashMap;

const SEED: u64 = 29;
const HOST_BACKENDS: [BackendKind; 2] = [BackendKind::Reference, BackendKind::Packed];

fn requests(n: u64, prompt_len: usize, n_new: usize) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len)
                .map(|i| ((id as usize * 11 + i * 5) % 31 + 1) as i32)
                .collect(),
            n_new,
        })
        .collect()
}

fn shared_prefix_requests(n: u64, prompt_len: usize, n_new: usize) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id,
            prompt: (0..prompt_len)
                .map(|i| {
                    if i < prompt_len / 2 {
                        ((i * 5) % 31 + 1) as i32
                    } else {
                        ((id as usize * 11 + i * 5) % 31 + 1) as i32
                    }
                })
                .collect(),
            n_new,
        })
        .collect()
}

fn assert_tokens_match(base: &[Response], out: &[Response], label: &str) {
    assert_eq!(base.len(), out.len(), "{label}: response count");
    for b in base {
        let r = out
            .iter()
            .find(|r| r.id == b.id)
            .unwrap_or_else(|| panic!("{label}: request {} missing", b.id));
        assert_eq!(b.tokens, r.tokens, "{label}: request {}", b.id);
    }
}

/// Oracle replay book from a spec-off run: request id -> the exact
/// token stream it will produce. MUST come from the same arena layout
/// and block length as the serving engine (int8 is lossy and group
/// scaling follows block geometry), which every caller here guarantees
/// by recording from the comparison baseline itself.
fn book_of(base: &[Response]) -> HashMap<u64, Vec<i32>> {
    base.iter().map(|r| (r.id, r.tokens.clone())).collect()
}

#[test]
fn every_draft_and_span_width_matches_spec_off() {
    for kind in HOST_BACKENDS {
        let engine =
            Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 0).unwrap();
        let reqs = requests(4, 6, 8);
        let base = Server::new(&engine, Policy::Continuous { max_active: 4 })
            .serve(reqs.clone())
            .unwrap();
        for k in [1usize, 3, 4] {
            let plans = [
                ("self", SpecPlan::self_draft(engine.artifacts(), k).unwrap()),
                ("tiny", SpecPlan::tiny_draft(engine.artifacts(), k).unwrap()),
                ("oracle", SpecPlan::oracle(book_of(&base), k).unwrap()),
            ];
            for (name, plan) in &plans {
                for policy in [
                    Policy::Continuous { max_active: 4 },
                    Policy::Batched { batch: 4 },
                    Policy::Fifo,
                ] {
                    let out = Server::new(&engine, policy)
                        .with_spec(plan)
                        .unwrap()
                        .serve(reqs.clone())
                        .unwrap();
                    assert_tokens_match(
                        &base,
                        &out,
                        &format!("{kind:?} {name} k={k} {policy:?}"),
                    );
                }
            }
        }
        let st = engine.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks, "{kind:?}: leaked blocks");
    }
}

#[test]
fn spec_with_chunked_prefill_survives_preemption_and_prefix_cache() {
    for kind in HOST_BACKENDS {
        let reqs = shared_prefix_requests(6, 8, 8);
        let roomy =
            Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 0).unwrap();
        let base = Server::new(&roomy, Policy::Fifo).serve(reqs.clone()).unwrap();
        // 10 blocks against 6 x 4-block sessions: preemption is forced,
        // and rejected-draft rollback runs concurrently with eviction
        // and copy-on-write prefix adoption.
        let tight =
            Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 10).unwrap();
        assert!(tight.enable_prefix_cache(0));
        let plan = SpecPlan::self_draft(tight.artifacts(), 3).unwrap();
        let out = Server::new(&tight, Policy::Continuous { max_active: 6 })
            .with_prefill_chunk(2)
            .with_spec(&plan)
            .unwrap()
            .serve(reqs.clone())
            .unwrap();
        assert!(
            out.iter().map(|r| r.evictions).sum::<u32>() > 0,
            "{kind:?}: 10 blocks cannot hold 6 x 4-block sessions"
        );
        assert_tokens_match(&base, &out, &format!("{kind:?} tight chunk+spec"));
        let st = tight.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks, "{kind:?}: leaked blocks");
    }
}

#[test]
fn sharded_workers_with_lanes_match_the_classic_single_engine() {
    for kind in HOST_BACKENDS {
        let single =
            Engine::load_with_arena(Artifacts::synthetic(SEED).unwrap(), kind, 4, 0).unwrap();
        let reqs = requests(8, 6, 6);
        let base = Server::new(&single, Policy::Fifo).serve(reqs.clone()).unwrap();
        let offsets = vec![0.0; reqs.len()];
        for workers in [1usize, 4] {
            let mut engine = ShardedEngine::load(
                Artifacts::synthetic(SEED).unwrap(),
                kind,
                4,
                24 * workers,
                workers,
            )
            .unwrap();
            let plan = SpecPlan::self_draft(engine.shard(0).artifacts(), 3).unwrap();
            let (out, _stats) = serve_sharded_stats_lanes(
                &mut engine,
                reqs.clone(),
                &offsets,
                4,
                0,
                2,
                Some(&plan),
            )
            .unwrap();
            assert_tokens_match(&base, &out, &format!("{kind:?} {workers}w lanes"));
        }
    }
}

#[test]
fn int8_arena_uses_sequential_verify_and_stays_exact() {
    for kind in HOST_BACKENDS {
        // The baseline must be the INT8 run, not f32: quantized KV is
        // lossy, so spec-on int8 must reproduce spec-off INT8 bitwise
        // (the sequential verify-then-commit path never writes an
        // unverified row, so the requantization sequence is identical).
        let engine = Engine::load_with_arena_mode(
            Artifacts::synthetic(SEED).unwrap(),
            kind,
            4,
            0,
            ArenaLayout::KvInt8,
        )
        .unwrap();
        let reqs = requests(4, 6, 8);
        let base = Server::new(&engine, Policy::Continuous { max_active: 4 })
            .serve(reqs.clone())
            .unwrap();
        for plan in [
            SpecPlan::self_draft(engine.artifacts(), 4).unwrap(),
            SpecPlan::oracle(book_of(&base), 4).unwrap(),
        ] {
            let out = Server::new(&engine, Policy::Continuous { max_active: 4 })
                .with_spec(&plan)
                .unwrap()
                .serve(reqs.clone())
                .unwrap();
            assert_tokens_match(&base, &out, &format!("{kind:?} int8 spec"));
        }
        let st = engine.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks, "{kind:?}: leaked blocks");
    }
}
