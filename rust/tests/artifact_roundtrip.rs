//! `.tpk` packed-artifact round-trip and corruption matrix.
//!
//! Contract under test: `write_tpk` -> `load_tpk` is bit-identical for
//! every matrix of the model, and the loader REJECTS every malformed
//! file with a `util::error` chain — it must never panic and never read
//! out of bounds, because a serving process mmaps whatever path it is
//! handed. Each corruption below patches a single aspect of a valid
//! file, so every validation rule in the loader is hit by at least one
//! case that is well-formed in every other respect.

use pim_llm::quant::artifact::{
    TPK_ALIGN, TPK_HEADER_BYTES, TPK_MAGIC, TPK_RECORD_BYTES,
};
use pim_llm::quant::{load_tpk, write_tpk, PackedModel};
use pim_llm::runtime::{Artifacts, Engine};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("pimllm-tpkrt-{}-{name}.tpk", std::process::id()))
}

/// A valid artifact's bytes + the artifacts it was packed from.
fn valid_artifact() -> (Vec<u8>, Artifacts) {
    let artifacts = Artifacts::synthetic(7).unwrap();
    let lowered = PackedModel::lower(&artifacts).unwrap();
    let path = tmp("base");
    write_tpk(&path, &lowered, &artifacts.manifest).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    (bytes, artifacts)
}

/// Write a patched copy, try to load it, clean up, return the result.
fn load_patched(
    name: &str,
    bytes: &[u8],
    artifacts: &Artifacts,
    patch: impl FnOnce(&mut Vec<u8>),
) -> Result<PackedModel, pim_llm::util::error::Error> {
    let mut b = bytes.to_vec();
    patch(&mut b);
    let path = tmp(name);
    std::fs::write(&path, &b).unwrap();
    let r = load_tpk(&path, artifacts);
    std::fs::remove_file(&path).ok();
    r
}

fn put_u64(b: &mut [u8], off: usize, v: u64) {
    b[off..off + 8].copy_from_slice(&v.to_le_bytes());
}

fn get_u64(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

#[test]
fn round_trip_is_bit_identical_and_engine_equivalent() {
    let (bytes, artifacts) = valid_artifact();
    let path = tmp("ok");
    std::fs::write(&path, &bytes).unwrap();
    // Loader accepts the untouched file and every plane round-trips.
    let lowered = PackedModel::lower(&artifacts).unwrap();
    let loaded = load_tpk(&path, &artifacts).unwrap();
    for ((name, lm), (_, rm)) in lowered.matrices().iter().zip(loaded.matrices().iter()) {
        assert_eq!(lm, rm, "'{name}' must round-trip bit-for-bit");
    }
    // And a full engine starts from it (no re-packing path involved).
    let e = Engine::load_packed_artifact(Artifacts::synthetic(7).unwrap(), &path, 0, 0).unwrap();
    let s = e.new_session().unwrap();
    assert_eq!(e.decode_step(s, 1, 0).unwrap().len(), e.vocab());
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncations_error_instead_of_panicking_or_reading_oob() {
    let (bytes, artifacts) = valid_artifact();
    let n_matrices = get_u64(&bytes, 80) as usize;
    let records_end = TPK_HEADER_BYTES + n_matrices * TPK_RECORD_BYTES;
    // Cut points spanning every structural region: empty file, mid
    // magic, mid header, mid record table, and inside the plane
    // payload (the final cut removes a whole alignment block, so it
    // always bites into the last plane section, not just tail padding).
    let cuts = [
        0usize,
        1,
        TPK_MAGIC.len() - 1,
        TPK_HEADER_BYTES - 1,
        TPK_HEADER_BYTES + TPK_RECORD_BYTES - 1,
        records_end - 1,
        bytes.len() - TPK_ALIGN,
    ];
    for cut in cuts {
        let r = load_patched(&format!("cut{cut}"), &bytes, &artifacts, |b| {
            b.truncate(cut);
        });
        assert!(r.is_err(), "truncation to {cut} bytes must be rejected");
    }
}

#[test]
fn header_corruptions_are_rejected() {
    let (bytes, artifacts) = valid_artifact();
    let cases: Vec<(&str, Box<dyn FnOnce(&mut Vec<u8>)>)> = vec![
        ("magic", Box::new(|b: &mut Vec<u8>| b[0] ^= 0xFF)),
        ("version", Box::new(|b: &mut Vec<u8>| {
            b[8..12].copy_from_slice(&99u32.to_le_bytes());
        })),
        ("endian", Box::new(|b: &mut Vec<u8>| b[12] ^= 0xFF)),
        // Geometry fields (vocab at 16) and eps bits (64) must match
        // the manifest exactly.
        ("vocab", Box::new(|b: &mut Vec<u8>| {
            let v = get_u64(b, 16);
            put_u64(b, 16, v + 1);
        })),
        ("eps", Box::new(|b: &mut Vec<u8>| b[64] ^= 0x01)),
        ("seed", Box::new(|b: &mut Vec<u8>| {
            let v = get_u64(b, 72);
            put_u64(b, 72, v ^ 1);
        })),
        ("n_matrices", Box::new(|b: &mut Vec<u8>| {
            let v = get_u64(b, 80);
            put_u64(b, 80, v + 1);
        })),
        // Absurd matrix count: the record-table size computation must
        // overflow-check, not allocate or wrap.
        ("n_matrices_huge", Box::new(|b: &mut Vec<u8>| {
            put_u64(b, 80, u64::MAX / 2);
        })),
    ];
    for (name, patch) in cases {
        let r = load_patched(name, &bytes, &artifacts, patch);
        assert!(r.is_err(), "header corruption '{name}' must be rejected");
        let msg = format!("{:?}", r.err().unwrap());
        assert!(!msg.is_empty(), "'{name}' must carry an error chain");
    }
}

#[test]
fn record_corruptions_are_rejected() {
    let (bytes, artifacts) = valid_artifact();
    let r0 = TPK_HEADER_BYTES; // first matrix record
    let cases: Vec<(&str, Box<dyn FnOnce(&mut Vec<u8>)>)> = vec![
        // Name: wrong identity, and not-UTF-8 bytes.
        ("name", Box::new(move |b: &mut Vec<u8>| b[r0] = b'z')),
        ("name_utf8", Box::new(move |b: &mut Vec<u8>| {
            b[r0] = 0xFF;
            b[r0 + 1] = 0xFE;
        })),
        // Shape fields disagreeing with the manifest / each other.
        ("k", Box::new(move |b: &mut Vec<u8>| {
            let v = get_u64(b, r0 + 32);
            put_u64(b, r0 + 32, v + 1);
        })),
        ("n", Box::new(move |b: &mut Vec<u8>| {
            let v = get_u64(b, r0 + 40);
            put_u64(b, r0 + 40, v + 1);
        })),
        ("words_per_col", Box::new(move |b: &mut Vec<u8>| {
            let v = get_u64(b, r0 + 48);
            put_u64(b, r0 + 48, v + 1);
        })),
        ("word_count", Box::new(move |b: &mut Vec<u8>| {
            let v = get_u64(b, r0 + 80);
            put_u64(b, r0 + 80, v + 1);
        })),
        // Scale: NaN bits, and valid-but-different bits.
        ("scale_nan", Box::new(move |b: &mut Vec<u8>| {
            b[r0 + 56..r0 + 60].copy_from_slice(&f32::NAN.to_bits().to_le_bytes());
        })),
        ("scale_value", Box::new(move |b: &mut Vec<u8>| {
            b[r0 + 56..r0 + 60].copy_from_slice(&0.123f32.to_bits().to_le_bytes());
        })),
        // Section placement: misaligned, inside the record table,
        // overlapping another section, and past EOF.
        ("misaligned", Box::new(move |b: &mut Vec<u8>| {
            let v = get_u64(b, r0 + 64);
            put_u64(b, r0 + 64, v + 8);
        })),
        ("into_records", Box::new(move |b: &mut Vec<u8>| {
            put_u64(b, r0 + 64, 0);
        })),
        ("overlap", Box::new(move |b: &mut Vec<u8>| {
            let plus = get_u64(b, r0 + 64);
            put_u64(b, r0 + 72, plus); // minus aliases plus
        })),
        ("past_eof", Box::new(move |b: &mut Vec<u8>| {
            put_u64(b, r0 + 64, (1u64 << 40) & !((TPK_ALIGN as u64) - 1));
        })),
        ("offset_overflow", Box::new(move |b: &mut Vec<u8>| {
            put_u64(b, r0 + 64, u64::MAX - (TPK_ALIGN as u64) + 1);
        })),
    ];
    for (name, patch) in cases {
        let r = load_patched(name, &bytes, &artifacts, patch);
        assert!(r.is_err(), "record corruption '{name}' must be rejected");
    }
}

#[test]
fn wrong_model_and_missing_file_are_errors() {
    let (bytes, _) = valid_artifact();
    // Same geometry, different seed: weights/scales differ, so the
    // seed binding must refuse the pairing.
    let other = Artifacts::synthetic(8).unwrap();
    let r = load_patched("wrongseed", &bytes, &other, |_| {});
    assert!(r.is_err(), "a .tpk from another model instance must not load");
    // A missing path is an error chain, not a panic.
    let missing = tmp("does-not-exist");
    assert!(load_tpk(&missing, &other).is_err());
}
