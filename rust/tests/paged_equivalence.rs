//! Equivalence tests for the paged KV-cache path: paging is a STORAGE
//! refactor, never a numerics change. Both host backends keep their
//! pre-paging contiguous decode step alive as an oracle
//! (`decode_step_contiguous` — the PR-2 numerics verbatim over
//! caller-owned `(n_layers, h, max_ctx, d_head)` tensors), and this
//! suite holds the paged path to BITWISE equality against it:
//!
//! * logits AND cache contents, single-step and over full generations,
//! * ragged `decode_batch` lanes at mixed positions,
//! * across block lengths (1, 3, 5, default, max_ctx),
//! * after an evict -> re-prefill cycle (the continuous scheduler's
//!   preemption path),
//! * and end to end: the continuous policy against FIFO on a
//!   preemption-forcing arena.
//!
//! Since PR 2 proved batched == sequential and PR 3 proved packed ==
//! reference bitwise, oracle equality here chains the paged/continuous
//! stack all the way back to the original decode-step numerics.

use pim_llm::runtime::artifacts::ModelInfo;
use pim_llm::runtime::packed::PackedBackend;
use pim_llm::runtime::reference::ReferenceBackend;
use pim_llm::runtime::{
    Artifacts, Backend, BackendKind, CacheArena, CacheHandle, CacheLayout, Engine,
};
use pim_llm::serving::{Policy, Request, Server};
use pim_llm::util::rng::Rng;
use std::sync::Arc;

/// A contiguous-oracle decode step: both host backends expose the same
/// shape, so the suite is generic over them.
type Oracle<'a> = &'a dyn Fn(&mut [f32], &mut [f32], i32, i32) -> Vec<f32>;

/// Run `steps` (token, position) pairs through the paged backend in one
/// session and through the contiguous oracle, asserting bitwise logits
/// at every step and bitwise cache contents at the end.
fn assert_session_matches_oracle(
    backend: &dyn Backend,
    arena: &mut CacheArena,
    oracle: Oracle<'_>,
    cache_numel: usize,
    steps: &[(i32, i32)],
    label: &str,
) {
    let s = backend.new_session(arena).unwrap();
    let (mut kc, mut vc) = (vec![0.0f32; cache_numel], vec![0.0f32; cache_numel]);
    for &(tok, pos) in steps {
        let paged = backend.decode_step(arena, s, tok, pos).unwrap();
        let want = oracle(&mut kc, &mut vc, tok, pos);
        assert_eq!(paged, want, "{label}: logits at pos {pos}");
    }
    assert_eq!(
        arena.gather_contiguous(s).unwrap(),
        (kc, vc),
        "{label}: final caches"
    );
    backend.drop_session(arena, s).unwrap();
}

/// A random small-but-varied model shape (dimensions avoid multiples of
/// the block length so block boundaries land mid-head).
fn random_model(rng: &mut Rng) -> ModelInfo {
    let h = [1usize, 2, 4][rng.range(0, 2)];
    ModelInfo {
        vocab: rng.range(8, 60),
        d: h * [3usize, 5, 8][rng.range(0, 2)],
        h,
        d_ff: rng.range(9, 40),
        n_layers: rng.range(1, 2),
        max_ctx: rng.range(8, 20),
        eps: 1e-5,
    }
}

#[test]
fn paged_matches_contiguous_oracle_across_block_lengths() {
    // Both backends, several models, block lengths from degenerate (1
    // position per block) through "one block holds the whole window".
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0xDEAD_BEEF).wrapping_add(3));
        let model = random_model(&mut rng);
        let artifacts = Arc::new(Artifacts::synthetic_with(seed, model.clone()).unwrap());
        let cache_numel = model.n_layers * model.h * model.max_ctx * (model.d / model.h);
        let n_steps = rng.range(3, model.max_ctx.min(10));
        let steps: Vec<(i32, i32)> = (0..n_steps)
            .map(|pos| (rng.range(0, model.vocab - 1) as i32, pos as i32))
            .collect();

        let reference = ReferenceBackend::new(Arc::clone(&artifacts)).unwrap();
        let packed = PackedBackend::new(Arc::clone(&artifacts)).unwrap();
        for block_len in [1usize, 3, 5, 0, model.max_ctx] {
            let layout = CacheLayout::with_block_len(&model, block_len);
            let mut arena = CacheArena::with_sessions(layout, 4).unwrap();
            assert_session_matches_oracle(
                &reference,
                &mut arena,
                &|kc, vc, t, p| reference.decode_step_contiguous(kc, vc, t, p).unwrap(),
                cache_numel,
                &steps,
                &format!("seed {seed} bl {block_len} reference"),
            );
            assert_session_matches_oracle(
                &packed,
                &mut arena,
                &|kc, vc, t, p| packed.decode_step_contiguous(kc, vc, t, p).unwrap(),
                cache_numel,
                &steps,
                &format!("seed {seed} bl {block_len} packed"),
            );
        }
    }
}

#[test]
fn ragged_decode_batch_matches_oracle_lanes() {
    // Lanes at mixed positions in ONE decode_batch call: each lane must
    // match its own oracle continuation exactly — logits and caches.
    for (kind, label) in [(BackendKind::Reference, "reference"), (BackendKind::Packed, "packed")]
    {
        let artifacts = Artifacts::synthetic(77).unwrap();
        let model = artifacts.manifest.model.clone();
        let cache_numel = model.n_layers * model.h * model.max_ctx * (model.d / model.h);
        let engine = Engine::load_with_arena(artifacts.clone(), kind, 3, 64).unwrap();
        let oracle_backend = ReferenceBackend::new(Arc::new(artifacts)).unwrap();
        // (The packed oracle is bitwise-equal to the reference oracle by
        // PR 3's guarantee, so one oracle serves both engines.)

        // Three lanes, advanced to ragged depths first.
        let prefixes: [&[i32]; 3] = [&[1, 2, 3], &[9], &[]];
        let mut handles: Vec<CacheHandle> = Vec::new();
        let mut oracles: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for prefix in prefixes {
            let s = engine.new_session().unwrap();
            let (mut kc, mut vc) = (vec![0.0f32; cache_numel], vec![0.0f32; cache_numel]);
            for (pos, &t) in prefix.iter().enumerate() {
                engine.decode_step(s, t, pos as i32).unwrap();
                oracle_backend
                    .decode_step_contiguous(&mut kc, &mut vc, t, pos as i32)
                    .unwrap();
            }
            handles.push(s);
            oracles.push((kc, vc));
        }
        // One ragged batch over all three lanes.
        let tokens = [4i32, 8, 2];
        let positions: Vec<i32> = prefixes.iter().map(|p| p.len() as i32).collect();
        let outs = engine.decode_batch(&handles, &tokens, &positions).unwrap();
        for (i, ((s, (kc, vc)), out)) in handles
            .iter()
            .zip(oracles.iter_mut())
            .zip(&outs)
            .enumerate()
        {
            let want = oracle_backend
                .decode_step_contiguous(kc, vc, tokens[i], positions[i])
                .unwrap();
            assert_eq!(out, &want, "{label} lane {i}: batched logits");
            assert_eq!(
                engine.gather_session(*s).unwrap(),
                (kc.clone(), vc.clone()),
                "{label} lane {i}: batched caches"
            );
        }
    }
}

#[test]
fn evict_and_reprefill_is_bitwise_deterministic() {
    // The continuous scheduler's preemption path in miniature: run a
    // session, free it (evict), replay the same tokens into a fresh
    // session (re-prefill), and continue — logits must be bitwise
    // identical to the oracle's uninterrupted run at every step, and
    // the final caches must match too.
    for (kind, label) in [(BackendKind::Reference, "reference"), (BackendKind::Packed, "packed")]
    {
        let artifacts = Artifacts::synthetic(123).unwrap();
        let model = artifacts.manifest.model.clone();
        let cache_numel = model.n_layers * model.h * model.max_ctx * (model.d / model.h);
        let engine = Engine::load_with_arena(artifacts.clone(), kind, 4, 16).unwrap();
        let oracle_backend = ReferenceBackend::new(Arc::new(artifacts)).unwrap();
        let full_free = engine.arena_status().free_blocks;

        let tokens = [5i32, 2, 9, 14, 3, 3, 8, 1, 0, 11];
        let split = 6usize; // evict after this many tokens

        // Oracle: uninterrupted run, recording logits per step.
        let (mut kc, mut vc) = (vec![0.0f32; cache_numel], vec![0.0f32; cache_numel]);
        let oracle_logits: Vec<Vec<f32>> = tokens
            .iter()
            .enumerate()
            .map(|(pos, &t)| {
                oracle_backend
                    .decode_step_contiguous(&mut kc, &mut vc, t, pos as i32)
                    .unwrap()
            })
            .collect();

        // Paged: run to `split`, evict, re-prefill from scratch, finish.
        let s1 = engine.new_session().unwrap();
        for (pos, &t) in tokens[..split].iter().enumerate() {
            let got = engine.decode_step(s1, t, pos as i32).unwrap();
            assert_eq!(got, oracle_logits[pos], "{label}: pre-evict pos {pos}");
        }
        engine.free_session(s1).unwrap();
        assert_eq!(
            engine.arena_status().free_blocks,
            full_free,
            "{label}: eviction must return every block"
        );
        let s2 = engine.new_session().unwrap();
        for (pos, &t) in tokens.iter().enumerate() {
            let got = engine.decode_step(s2, t, pos as i32).unwrap();
            assert_eq!(got, oracle_logits[pos], "{label}: post-evict pos {pos}");
        }
        assert_eq!(
            engine.gather_session(s2).unwrap(),
            (kc, vc),
            "{label}: caches after re-prefill"
        );
        engine.free_session(s2).unwrap();
    }
}

#[test]
fn continuous_serving_matches_fifo_under_forced_preemption() {
    // End-to-end acceptance: on an arena too small for the concurrent
    // worst case, the continuous policy must preempt and STILL produce
    // exactly the tokens FIFO produces on a roomy engine — on both host
    // backends.
    let mut rng = Rng::new(0xC0FFEE);
    let requests: Vec<Request> = (0..7u64)
        .map(|id| Request {
            id,
            prompt: (0..rng.range(1, 5))
                .map(|_| rng.range(1, 60) as i32)
                .collect(),
            n_new: rng.range(4, 10),
        })
        .collect();
    for kind in [BackendKind::Reference, BackendKind::Packed] {
        let roomy = Engine::load_with(Artifacts::synthetic(9).unwrap(), kind).unwrap();
        let fifo = Server::new(&roomy, Policy::Fifo).serve(requests.clone()).unwrap();
        let tight =
            Engine::load_with_arena(Artifacts::synthetic(9).unwrap(), kind, 4, 9).unwrap();
        let out = Server::new(&tight, Policy::Continuous { max_active: 7 })
            .serve(requests.clone())
            .unwrap();
        assert_eq!(out.len(), requests.len());
        assert!(
            out.iter().map(|r| r.evictions).sum::<u32>() > 0,
            "{kind:?}: the 9-block arena must force preemption"
        );
        for f in &fifo {
            let c = out.iter().find(|c| c.id == f.id).unwrap();
            assert_eq!(f.tokens, c.tokens, "{kind:?} request {}", f.id);
        }
        // No leaks across the whole serve.
        let st = tight.arena_status();
        assert_eq!(st.free_blocks, st.total_blocks, "{kind:?}");
    }
}
