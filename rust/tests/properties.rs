//! Property-based tests over the simulator substrates, driven by the
//! in-crate SplitMix64 PRNG (the offline build has no proptest; the
//! shrink-free random-sweep style below covers the same invariants).
//!
//! The headline property: the closed-form dataflow cycle models equal
//! the cycle-accurate wavefront stepper on every random GEMM shape —
//! i.e. the SCALE-Sim-style analytical mode is exact, not approximate.

use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, Arch};
use pim_llm::models;
use pim_llm::pim::mapping::{map_model, OpMapping};
use pim_llm::systolic::dataflow::{gemm_cycles, Dataflow};
use pim_llm::systolic::wavefront::simulate_gemm;
use pim_llm::util::rng::Rng;
use pim_llm::workload::{decode_ops, stats, Precision};

const CASES: usize = 200;

#[test]
fn analytical_equals_wavefront_on_random_shapes() {
    let mut rng = Rng::new(0xDEC0DE);
    for case in 0..CASES {
        let m = rng.range(1, 40);
        let k = rng.range(1, 40);
        let n = rng.range(1, 40);
        let r = rng.range(1, 12);
        let c = rng.range(1, 12);
        for df in Dataflow::ALL {
            let analytical = gemm_cycles(m, k, n, r, c, df);
            let stepped = simulate_gemm(m, k, n, r, c, df);
            assert_eq!(
                analytical, stepped.cycles,
                "case {case}: ({m},{k},{n}) on {r}x{c} {df:?}"
            );
            assert_eq!(
                stepped.macs,
                (m * k * n) as u64,
                "work conservation, case {case}"
            );
        }
    }
}

#[test]
fn cycles_monotone_in_gemm_dims() {
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..CASES {
        let m = rng.range(1, 200);
        let k = rng.range(1, 200);
        let n = rng.range(1, 200);
        for df in Dataflow::ALL {
            let base = gemm_cycles(m, k, n, 32, 32, df);
            assert!(gemm_cycles(m + rng.range(1, 50), k, n, 32, 32, df) >= base);
            assert!(gemm_cycles(m, k + rng.range(1, 50), n, 32, 32, df) >= base);
            assert!(gemm_cycles(m, k, n + rng.range(1, 50), 32, 32, df) >= base);
        }
    }
}

#[test]
fn workload_macs_partition_exactly_for_random_models() {
    // Random-but-valid decoder configs: the W1A8/W8A8 partition must be
    // exhaustive and match the closed forms for ANY hyper-parameters.
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..CASES {
        let h = rng.range(1, 32);
        let d = h * rng.range(1, 64); // divisible by h
        let model = models::LlmConfig::new(
            "random",
            0,
            d,
            h,
            rng.range(1, 4096),
            rng.range(1, 48),
        );
        let l = rng.range(1, 4096);
        let ops = decode_ops(&model, l);
        let s = stats(&ops);
        assert_eq!(s.w1a8_macs, model.projection_macs());
        assert_eq!(s.w8a8_macs, model.attention_macs(l));
        assert_eq!(s.total_macs, s.w1a8_macs + s.w8a8_macs);
        // Every op is an MVM and belongs to exactly one side.
        for op in &ops {
            assert_eq!(op.n, 1);
            match op.precision {
                Precision::W1A8 => assert!(!op.is_attention()),
                Precision::W8A8 => assert!(op.is_attention()),
            }
        }
    }
}

#[test]
fn crossbar_mapping_covers_all_weights() {
    // Mapped crossbar capacity always >= weight count; utilization in
    // (0, 1]; crossbar count exact per-op.
    let arch = ArchConfig::paper_45nm();
    let mut rng = Rng::new(0xF00D);
    for _ in 0..CASES {
        let h = rng.range(1, 16);
        let model = models::LlmConfig::new(
            "random",
            0,
            h * rng.range(1, 96),
            h,
            rng.range(1, 8192),
            rng.range(1, 40),
        );
        let ops = decode_ops(&model, 128);
        let mapping = map_model(&arch, &ops);
        let capacity = mapping.total_crossbars * arch.weights_per_crossbar() as u64;
        assert!(capacity >= model.projection_weights());
        assert!(mapping.utilization > 0.0 && mapping.utilization <= 1.0);
        for op in ops.iter().filter(|o| o.precision == Precision::W1A8) {
            let om = OpMapping::for_op(&arch, op);
            let cap = om.crossbars() * arch.weights_per_crossbar() as u64;
            assert!(cap >= (op.m * op.k) as u64);
        }
    }
}

#[test]
fn simulation_invariants_hold_across_random_points() {
    // For random (model, context): latencies/energies positive and
    // finite, breakdown sums to total, PIM-LLM never slower than
    // TPU-LLM (projections never dominate on PIM).
    let arch = ArchConfig::paper_45nm();
    let zoo = models::table2_models();
    let mut rng = Rng::new(0x5EED);
    for _ in 0..60 {
        let model = &zoo[rng.range(0, zoo.len() - 1)];
        let l = rng.range(1, 4096);
        let p = coordinator::simulate(&arch, model, l, Arch::PimLlm);
        let t = coordinator::simulate(&arch, model, l, Arch::TpuLlm);
        for r in [&p, &t] {
            assert!(r.latency_s().is_finite() && r.latency_s() > 0.0);
            assert!(r.energy.total_j().is_finite() && r.energy.total_j() > 0.0);
            let items_sum: f64 = r.breakdown.items().iter().map(|(_, v)| v).sum();
            assert!((items_sum - r.latency_s()).abs() < 1e-9 * r.latency_s());
            let frac_sum: f64 = r.breakdown.fractions().as_vec().iter().map(|(_, v)| v).sum();
            assert!((frac_sum - 1.0).abs() < 1e-9);
        }
        assert!(
            p.latency_s() < t.latency_s(),
            "{} l={l}: hybrid must win on latency",
            model.name
        );
        // Hybrid's systolic time equals baseline's attention-only time.
        assert!(p.breakdown.systolic_s <= t.breakdown.systolic_s);
    }
}

#[test]
fn speedup_scales_with_projection_share() {
    // The more MACs live in projections (the PIM side), the larger the
    // hybrid speedup — Fig. 1b's motivation connected to Fig. 5.
    let arch = ArchConfig::paper_45nm();
    let mut rng = Rng::new(0xACE);
    for _ in 0..40 {
        let model = models::by_name("OPT-2.7B").unwrap();
        let l1 = rng.range(1, 2000);
        let l2 = l1 + rng.range(100, 2096);
        // larger l => smaller projection share => smaller speedup
        let s1 = coordinator::speedup(&arch, &model, l1);
        let s2 = coordinator::speedup(&arch, &model, l2);
        assert!(
            s2 < s1,
            "l={l1}->{l2}: speedup must fall ({s1} -> {s2})"
        );
    }
}
