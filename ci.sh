#!/usr/bin/env bash
# Tier-1 verification, offline-enforced.
#
# `--offline` makes any attempt to touch the network (i.e. any external
# dependency sneaking into the default feature set) a hard failure —
# the no-network invariant of this repo's default build.
set -euo pipefail
cd "$(dirname "$0")"

echo "== lint: rustfmt =="
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --all -- --check
else
  echo "skipped: rustfmt not installed (rustup component add rustfmt)"
fi

echo "== lint: clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets --offline -- -D warnings
else
  echo "skipped: clippy not installed (rustup component add clippy)"
fi

echo "== tier-1: release build (offline) =="
cargo build --release --offline

echo "== tier-1: tests (offline) =="
# Runs every test target, including the batched-path suites
# tests/batch_equivalence.rs and tests/serving_determinism.rs.
cargo test -q --offline

echo "== equivalence + allocator suites (offline, explicit) =="
# Named explicitly so a test-target wiring mistake (a file dropped from
# the harness) cannot silently skip the bitwise-equivalence guarantees.
cargo test -q --offline --test packed_equivalence
cargo test -q --offline --test batch_equivalence
cargo test -q --offline --test paged_equivalence
cargo test -q --offline --test kvcache_properties
cargo test -q --offline --test prefix_equivalence
cargo test -q --offline --test shard_determinism
cargo test -q --offline --test artifact_roundtrip
cargo test -q --offline --test obs_trace
cargo test -q --offline --test kvq_equivalence
cargo test -q --offline --test chunked_prefill
cargo test -q --offline --test spec_equivalence

echo "== smoke: runtime backend selection =="
# Exercise the --backend flag end to end (synthetic-model fallback, no
# artifacts needed) so backend selection can't silently rot: `validate`
# must reproduce the golden generation bit-exactly on BOTH host
# backends, and a tiny batched `serve` must complete on packed.
cargo run -q --release --offline --bin repro -- validate --backend reference
cargo run -q --release --offline --bin repro -- validate --backend packed
cargo run -q --release --offline --bin repro -- serve --backend packed \
  --requests 4 --prompt-len 4 --new-tokens 8 --batch 4

echo "== smoke: continuous batching under arena pressure =="
# The continuous policy on BOTH host backends, on an arena deliberately
# too small for every session's worst case (6 requests x 2 blocks
# against 8 blocks), so the preempt -> requeue -> re-prefill path runs
# end to end in CI, not just in unit tests.
cargo run -q --release --offline --bin repro -- serve --backend reference \
  --policy continuous --requests 6 --prompt-len 4 --new-tokens 16 \
  --max-active 6 --arena-blocks 8
cargo run -q --release --offline --bin repro -- serve --backend packed \
  --policy continuous --requests 6 --prompt-len 4 --new-tokens 16 \
  --max-active 6 --arena-blocks 8

echo "== smoke: copy-on-write prefix cache under arena pressure =="
# The prefix cache on BOTH host backends against a deliberately tight
# arena (10 requests sharing a 6-token system prefix, 10 blocks of 4
# positions), so the shared-block preemption path — reclaim index pins,
# preempt a sharer, re-admit and re-share — executes end to end in CI.
cargo run -q --release --offline --bin repro -- serve --backend reference \
  --policy continuous --prefix-cache --requests 10 --prompt-len 12 \
  --new-tokens 8 --max-active 8 --arena-blocks 10 --block-len 4
cargo run -q --release --offline --bin repro -- serve --backend packed \
  --policy continuous --prefix-cache --requests 10 --prompt-len 12 \
  --new-tokens 8 --max-active 8 --arena-blocks 10 --block-len 4

echo "== smoke: sharded multi-worker serving against a tight arena =="
# Four workers over ONE partitioned arena (24 blocks total = 6 per
# shard) on BOTH host backends: hash placement, per-shard continuous
# ticks, work stealing, and per-shard preemption all run end to end.
cargo run -q --release --offline --bin repro -- serve --backend reference \
  --policy sharded --workers 4 --requests 12 --prompt-len 4 \
  --new-tokens 12 --max-active 3 --arena-blocks 24
cargo run -q --release --offline --bin repro -- serve --backend packed \
  --policy sharded --workers 4 --requests 12 --prompt-len 4 \
  --new-tokens 12 --max-active 3 --arena-blocks 24

echo "== smoke: int8 KV arena at serving scale =="
# --kv-quant int8 on BOTH host backends, with the SAME tight block
# counts as the f32 smokes above (identical paging pressure at ~3.7x
# fewer bytes): continuous batching with the prefix cache (shared
# blocks + partial-tail adoption + preemption over quantized rows),
# and sharded x4 over one partitioned int8 arena.
for be in reference packed; do
  cargo run -q --release --offline --bin repro -- serve --backend "$be" \
    --kv-quant int8 --policy continuous --prefix-cache --requests 10 \
    --prompt-len 12 --new-tokens 8 --max-active 8 --arena-blocks 10 \
    --block-len 4
  cargo run -q --release --offline --bin repro -- serve --backend "$be" \
    --kv-quant int8 --policy sharded --workers 4 --requests 12 \
    --prompt-len 4 --new-tokens 12 --max-active 3 --arena-blocks 24
done

echo "== smoke: prefill/decode lanes (chunked prefill + speculative decoding) =="
# The lane scheduler end to end on BOTH host backends: chunked prefill
# with a self-model draft on a deliberately tight continuous arena
# (preemption + rejected-draft rollback both fire), and chunked + tiny
# draft across the sharded x4 partitioned arena. Output equality with
# the classic scheduler is pinned by tests/spec_equivalence.rs; this
# exercises the CLI wiring and the pressured paths at serving scale.
for be in reference packed; do
  cargo run -q --release --offline --bin repro -- serve --backend "$be" \
    --policy continuous --requests 6 --prompt-len 8 --new-tokens 12 \
    --max-active 6 --arena-blocks 12 --block-len 4 \
    --prefill-chunk 3 --spec-draft self --spec-k 3
  cargo run -q --release --offline --bin repro -- serve --backend "$be" \
    --policy sharded --workers 4 --requests 12 --prompt-len 8 \
    --new-tokens 12 --max-active 3 --arena-blocks 32 --block-len 4 \
    --prefill-chunk 4 --spec-draft tiny --spec-k 4
done
# A flag typo must fail loudly (satellite: the CLI stops eating typos).
if cargo run -q --release --offline --bin repro -- serve \
  --prefil-chunk 8 --requests 2 2>/dev/null; then
  echo "ERROR: misspelled --prefil-chunk should have been rejected"
  exit 1
fi

echo "== smoke: observability on the sharded serving path =="
# Tracing + metrics + per-tick validation end to end on BOTH host
# backends: the emitted Chrome trace must round-trip through the
# in-crate JSON parser (`repro trace-check`), which asserts a nonzero
# event count and per-track monotonic timestamps — the Perfetto-schema
# contract, enforced in CI on a real serve, not just unit fixtures.
OBS_TMP="$(mktemp -d)"
# One EXIT trap covers both temp dirs (a second trap would replace
# this one, leaking the first directory).
trap 'rm -rf "$OBS_TMP" "${TPK_TMP:-$OBS_TMP}"' EXIT
for be in reference packed; do
  cargo run -q --release --offline --bin repro -- serve --backend "$be" \
    --policy sharded --workers 4 --requests 12 --prompt-len 4 \
    --new-tokens 12 --max-active 3 --arena-blocks 24 \
    --trace "$OBS_TMP/trace_$be.json" --metrics --validate-every 4
  test -s "$OBS_TMP/trace_$be.json"
  cargo run -q --release --offline --bin repro -- trace-check \
    --trace "$OBS_TMP/trace_$be.json"
done

echo "== smoke: .tpk packed-artifact round trip =="
# `repro pack` writes the versioned packed artifact; validate must then
# reproduce the golden generation bit-exactly from the mmap'd planes
# (no per-matrix re-pack), with the plain packed backend alongside as
# the reference point; finally sharded serving starts all its workers
# from the ONE loaded artifact.
TPK_TMP="$(mktemp -d)"  # cleaned by the shared EXIT trap above
cargo run -q --release --offline --bin repro -- pack --out "$TPK_TMP/model.tpk"
test -s "$TPK_TMP/model.tpk"
cargo run -q --release --offline --bin repro -- validate --backend packed \
  --artifact "$TPK_TMP/model.tpk"
cargo run -q --release --offline --bin repro -- validate --backend packed
cargo run -q --release --offline --bin repro -- serve --backend packed \
  --policy sharded --workers 4 --requests 12 --prompt-len 4 \
  --new-tokens 12 --max-active 3 --arena-blocks 24 \
  --artifact "$TPK_TMP/model.tpk"
# --artifact on a non-packed backend must be refused, not ignored.
if cargo run -q --release --offline --bin repro -- validate \
  --backend reference --artifact "$TPK_TMP/model.tpk" 2>/dev/null; then
  echo "ERROR: --artifact with --backend reference should have failed"
  exit 1
fi

echo "== bench + example targets compile (offline) =="
cargo build --benches --offline
cargo build --examples --offline

echo "== bench manifests: every advertised BENCH_*.json is checked in and parses =="
# A bench that claims to emit a trajectory file at the repo root must
# have that file committed (provisional first points included), so the
# README's bench map never dangles.
for f in $(grep -ho 'BENCH_[A-Za-z0-9_]*\.json' rust/benches/*.rs | sort -u); do
  if [ ! -f "$f" ]; then
    echo "ERROR: rust/benches advertises $f but it is not checked in"
    exit 1
  fi
done
# Existence is not enough: each artifact must parse with the in-crate
# JSON parser and carry its bench's required keys, so an interrupted
# bench run can't leave a truncated file that CI waves through.
cargo run -q --release --offline --bin repro -- bench-check --dir .

echo "ci.sh: all green"
