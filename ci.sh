#!/usr/bin/env bash
# Tier-1 verification, offline-enforced.
#
# `--offline` makes any attempt to touch the network (i.e. any external
# dependency sneaking into the default feature set) a hard failure —
# the no-network invariant of this repo's default build.
set -euo pipefail
cd "$(dirname "$0")"

echo "== lint: rustfmt =="
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --all -- --check
else
  echo "skipped: rustfmt not installed (rustup component add rustfmt)"
fi

echo "== lint: clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets --offline -- -D warnings
else
  echo "skipped: clippy not installed (rustup component add clippy)"
fi

echo "== tier-1: release build (offline) =="
cargo build --release --offline

echo "== tier-1: tests (offline) =="
# Runs every test target, including the batched-path suites
# tests/batch_equivalence.rs and tests/serving_determinism.rs.
cargo test -q --offline

echo "== bench + example targets compile (offline) =="
cargo build --benches --offline
cargo build --examples --offline

echo "ci.sh: all green"
