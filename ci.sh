#!/usr/bin/env bash
# Tier-1 verification, offline-enforced.
#
# `--offline` makes any attempt to touch the network (i.e. any external
# dependency sneaking into the default feature set) a hard failure —
# the no-network invariant of this repo's default build.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: release build (offline) =="
cargo build --release --offline

echo "== tier-1: tests (offline) =="
cargo test -q --offline

echo "== bench + example targets compile (offline) =="
cargo build --benches --offline
cargo build --examples --offline

echo "ci.sh: all green"
