//! Dataflow explorer: interactive-ish tour of the systolic-array
//! simulator behind paper Fig. 4. For a chosen model/context/array size
//! it prints per-op-class cycles under OS / WS / IS, validates the
//! analytical formulas against the cycle-accurate wavefront stepper on
//! scaled-down shapes, and sweeps array sizes to show where the paper's
//! 32x32 choice sits.
//!
//! Run: `cargo run --release --example dataflow_explorer -- \
//!        --model OPT-6.7B --context 1024 --rows 32 --cols 32`

use pim_llm::models;
use pim_llm::systolic::dataflow::{decode_step_cycles, gemm_cycles, Dataflow};
use pim_llm::systolic::wavefront::simulate_gemm;
use pim_llm::util::cli::Args;
use pim_llm::util::error::{anyhow, Result};
use pim_llm::workload::{decode_ops, OpKind};
use std::collections::BTreeMap;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    args.expect_known(&["model", "context", "rows", "cols"])?;
    let model = models::by_name(&args.str_or("model", "OPT-6.7B"))
        .ok_or_else(|| anyhow!("unknown model"))?;
    let l = args.usize_or("context", 1024)?;
    let rows = args.usize_or("rows", 32)?;
    let cols = args.usize_or("cols", 32)?;

    println!(
        "== {} @ l={l} on a {rows}x{cols} systolic array ==\n",
        model.name
    );

    // Per-op-class cycle shares under each dataflow.
    println!("{:<18} {:>14} {:>14} {:>14}", "op class", "OS", "WS", "IS");
    let ops = decode_ops(&model, l);
    let mut by_kind: BTreeMap<String, [u64; 3]> = BTreeMap::new();
    for op in &ops {
        let e = by_kind
            .entry(format!("{:?}", op.kind))
            .or_insert([0, 0, 0]);
        for (i, df) in Dataflow::ALL.iter().enumerate() {
            e[i] += gemm_cycles(op.m, op.k, op.n, rows, cols, *df);
        }
    }
    for (kind, [os, ws, is]) in &by_kind {
        println!("{kind:<18} {os:>14} {ws:>14} {is:>14}");
    }
    for df in Dataflow::ALL {
        let total = decode_step_cycles(&model, l, rows, cols, df);
        println!(
            "TOTAL {:<12} {total:>14} cycles = {:.2} ms @100MHz",
            df.short_name(),
            total as f64 * 10e-9 * 1e3
        );
    }

    // Cross-validate analytical formulas with the wavefront stepper on
    // scaled-down versions of the real op shapes.
    println!("\n== wavefront cross-validation (scaled shapes, 8x8 array) ==");
    let samples = [
        (OpKind::QkvProjection, 64, 64, 1),
        (OpKind::AttentionScore, 32, 16, 1),
        (OpKind::AttentionValue, 16, 32, 1),
        (OpKind::FfIntermediate, 96, 24, 1),
    ];
    for (kind, m, k, n) in samples {
        for df in Dataflow::ALL {
            let analytical = gemm_cycles(m, k, n, 8, 8, df);
            let stepped = simulate_gemm(m, k, n, 8, 8, df);
            assert_eq!(analytical, stepped.cycles, "{kind:?} {df:?}");
            assert_eq!(stepped.macs, (m * k * n) as u64);
        }
        println!("{kind:?} ({m}x{k}x{n}): analytical == cycle-accurate for OS/WS/IS");
    }

    // Array-size sweep: where does 32x32 sit?
    println!("\n== array size sweep (OS dataflow, ms/token @100MHz) ==");
    for dim in [8usize, 16, 32, 64, 128] {
        let total = decode_step_cycles(&model, l, dim, dim, Dataflow::OutputStationary);
        println!(
            "{dim:>4}x{dim:<4} {:>14} cycles = {:8.2} ms",
            total,
            total as f64 * 10e-9 * 1e3
        );
    }
    println!("\n(paper uses 32x32: beyond it, MVM N=1 leaves columns idle and");
    println!(" the skew overhead grows; below it, the K-dim stream dominates)");
    Ok(())
}
