//! Edge serving — the end-to-end driver required by the reproduction:
//! load the 1-bit decoder (AOT artifacts when present, else the offline
//! synthetic model), serve a batch of requests through the runtime, and
//! report latency/throughput (queue wait, TTFT, and end-to-end
//! percentiles); then project the same workload onto the simulated
//! PIM-LLM and TPU-LLM hardware for the paper's edge-deployment metrics
//! (tokens/s, tokens/J, words/battery).
//!
//! Scheduling: `--policy fifo|rr|batched|continuous` selects the
//! scheduler. `batched` issues one `decode_batch` over all active
//! sessions per tick (one weight traversal per step for the whole
//! batch) with worst-case KV-block reservations per request;
//! `continuous` admits and retires sessions every tick against the
//! paged KV-cache arena, preempting the youngest session under arena
//! pressure. Without `--policy`, `--batch B > 0` selects batched and
//! `--batch 0` round-robin (the historical knobs). All policies produce
//! identical tokens. `--arena-blocks`/`--block-len` size the arena
//! (0 = defaults) — a small arena is what makes `continuous` show its
//! packing advantage (and its preemptions) on this tiny model.
//!
//! `--policy sharded --workers W` switches to the multi-worker engine:
//! the SAME total arena capacity is partitioned into W `Send`-able
//! shards, each owned by one continuous-batching worker thread
//! (`--max-active` lanes PER worker), with deterministic hash placement
//! and cross-shard work stealing. The example then runs the identical
//! workload on a 1-worker engine at equal total capacity and asserts
//! the tokens are byte-identical — worker count is a throughput knob,
//! never a numerics knob.
//!
//! Prefix sharing: `--prefix-cache` (with optional `--prefix-cap E`)
//! turns on the copy-on-write prefix cache — every request here shares
//! one system prompt over the first half of its tokens, so matched
//! prefill positions are served from cached blocks instead of being
//! re-decoded, with bit-identical tokens (asserted below against the
//! cache-off run).
//!
//! Observability: `--trace <path>` records every tick, admission,
//! preemption, steal, prefix hit and kernel span into per-shard ring
//! buffers and writes a Chrome trace-event JSON (open it in Perfetto);
//! `--metrics` prints the counter/gauge/histogram snapshot. Both are
//! inert — the token assertions below run identically with them on.
//!
//! KV quantization: `--kv-quant int8` stores cached K/V as group-scaled
//! int8 (~4x the resident sessions per arena byte; host backends only).
//! int8 tokens are deterministic and scheduler-independent, but lossy
//! against f32, and prefix adoption of a PARTIAL block inherits the
//! donor's coarser scale — so the bitwise token assertions below only
//! run where bitwise equality is guaranteed. The example always ends
//! with an f32-vs-int8 comparison at EQUAL arena bytes showing the
//! resident-session / preemption trade.
//!
//! Run: `cargo run --release --example edge_serving -- \
//!        --requests 32 --prompt-len 8 --new-tokens 16 --batch 8 \
//!        [--policy continuous --arena-blocks 24] [--kv-quant int8] \
//!        [--prefix-cache] [--backend reference|packed] \
//!        [--trace /tmp/edge.json] [--metrics]`

use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{token_loop, Arch};
use pim_llm::models;
use pim_llm::obs::export::write_chrome_trace;
use pim_llm::runtime::{
    ArenaLayout, Artifacts, BackendKind, CacheLayout, DraftSpec, Engine, ShardedEngine, SpecPlan,
    DEFAULT_SPEC_K,
};
use pim_llm::serving::{
    serve_sharded_stats, serve_sharded_stats_lanes, shard_report, LatencyStats, Policy, Request,
    Server,
};
use pim_llm::util::cli::Args;
use pim_llm::util::error::Result;
use pim_llm::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    args.expect_known(&[
        "requests",
        "prompt-len",
        "new-tokens",
        "max-active",
        "batch",
        "workers",
        "policy",
        "arena-blocks",
        "block-len",
        "kv-quant",
        "prefix-cache",
        "prefix-cap",
        "backend",
        "trace",
        "metrics",
        "prefill-chunk",
        "spec-draft",
        "spec-k",
    ])?;
    let n_requests = args.usize_or("requests", 32)?;
    let prompt_len = args.usize_or("prompt-len", 8)?;
    let new_tokens = args.usize_or("new-tokens", 16)?;
    let max_active = args.usize_or("max-active", 4)?;
    // Historical default (no --policy given): batched with 8 lanes. With
    // an explicit --policy, the batch default drops to 0 so --max-active
    // governs the lane count unless --batch is passed too — the same
    // precedence `repro serve` uses.
    let batch = args.usize_or("batch", if args.get("policy").is_some() { 0 } else { 8 })?;
    let workers = args.usize_or("workers", 1)?;
    let policy = Policy::from_flags(args.get("policy"), batch, max_active, workers)?;
    let arena_blocks = args.usize_or("arena-blocks", 0)?;
    let block_len = args.usize_or("block-len", 0)?;
    let kv_quant = ArenaLayout::from_name(&args.str_or("kv-quant", "f32"))?;
    let prefix_cache = args.flag("prefix-cache")?;
    let prefix_cap = args.usize_or("prefix-cap", 0)?;
    // Lane-scheduler pass-through: chunked prefill + speculative
    // decoding, both scheduling-only (token assertions below hold with
    // them on).
    let prefill_chunk = args.usize_or("prefill-chunk", 0)?;
    let spec_draft = DraftSpec::from_flag(&args.str_or("spec-draft", "off"))?;
    let spec_k = args.usize_or("spec-k", DEFAULT_SPEC_K)?;

    // The sharded policy partitions ONE arena across worker threads and
    // has its own 1-vs-N scaling demonstration.
    if let Policy::Sharded {
        workers,
        max_active,
    } = policy
    {
        return sharded_scaling(
            &args,
            workers,
            max_active,
            n_requests,
            prompt_len,
            new_tokens,
            arena_blocks,
            block_len,
            kv_quant,
            prefix_cache,
            prefix_cap,
            prefill_chunk,
            spec_draft,
            spec_k,
        );
    }

    // ----------------------------------------------------------------
    // Functional serving on the runtime backend (`--backend packed`
    // selects the bitplane popcount executor — identical tokens, less
    // weight traffic).
    // ----------------------------------------------------------------
    let engine = Engine::load_default_with_arena_mode(
        BackendKind::resolve(args.backend())?,
        block_len,
        arena_blocks,
        kv_quant,
    )?;
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    let metrics = args.flag("metrics")?;
    if trace_path.is_some() || metrics {
        engine.obs().set_enabled(true);
    }
    if prefix_cache && !engine.enable_prefix_cache(prefix_cap) {
        println!(
            "note: backend {} cannot share arena blocks — prefix cache off",
            engine.backend_name()
        );
    }
    let arena = engine.arena_status();
    println!(
        "engine up: backend={} platform={} tiny-1bit d={} ({} layers), policy={policy:?}, \
         KV arena {} blocks x {} positions ({} bytes, kv={}), prefix cache {}",
        engine.backend_name(),
        engine.platform(),
        engine.artifacts.manifest.model.d,
        engine.artifacts.manifest.model.n_layers,
        arena.total_blocks,
        arena.block_len,
        arena.total_bytes,
        engine.arena_mode().name(),
        if engine.prefix_enabled() { "on" } else { "off" }
    );

    let requests = workload(engine.vocab(), n_requests, prompt_len, new_tokens);

    let plan = spec_plan(spec_draft, spec_k, engine.artifacts(), &requests, block_len, kv_quant)?;
    let t0 = Instant::now();
    let mut server = Server::new(&engine, policy).with_prefill_chunk(prefill_chunk);
    if let Some(p) = &plan {
        server = server.with_spec(p)?;
    }
    let responses = server.serve(requests.clone())?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = LatencyStats::from_responses(&responses, wall);

    println!(
        "\nserved {} requests ({} tokens) in {:.2}s on {} numerics",
        stats.n,
        stats.total_tokens,
        wall,
        engine.backend_name()
    );
    println!("  throughput       : {:8.1} tok/s", stats.tokens_per_s);
    println!("  mean svc latency : {:8.3} s", stats.mean_service_s);
    println!(
        "  p50 / p95 / p99  : {:.3} / {:.3} / {:.3} s",
        stats.p50_service_s, stats.p95_service_s, stats.p99_service_s
    );
    println!(
        "  TTFT mean/p50/p95: {:.3} / {:.3} / {:.3} s",
        stats.mean_ttft_s, stats.p50_ttft_s, stats.p95_ttft_s
    );
    println!(
        "  queue mean/p95   : {:.3} / {:.3} s",
        stats.mean_queue_s, stats.p95_queue_s
    );
    println!("  preemptions      : {}", stats.evictions);
    if let Some(ps) = engine.prefix_stats() {
        println!("  {}", ps.report());
    }
    if let Some(path) = &trace_path {
        let tracks = vec![(engine.obs().shard(), engine.obs().trace.drain())];
        write_chrome_trace(path, &tracks)?;
        println!(
            "  trace            : {} events -> {}",
            tracks[0].1.len(),
            path.display()
        );
    }
    if metrics {
        print!("{}", engine.metrics_snapshot().render());
    }
    // The comparison runs below are about tokens, not telemetry — stop
    // recording so their events cannot blur the written trace's story.
    engine.obs().set_enabled(false);

    // All responses complete and deterministic per prompt.
    assert!(responses
        .iter()
        .all(|r| r.tokens.len() == prompt_len + new_tokens));

    // The prefix cache is a pure scheduling/storage optimization in f32:
    // the tokens must be identical to a cache-off run of the same
    // workload. In int8 a partial-block adoption keeps the donor's
    // coarser group scale, so the guarantee weakens to bounded — the
    // bitwise check only runs on the bit-exact layout.
    if engine.prefix_enabled() {
        if kv_quant == ArenaLayout::F32 {
            let off = Engine::load_default_with_arena(
                BackendKind::resolve(args.backend())?,
                block_len,
                arena_blocks,
            )?;
            let cold = Server::new(&off, policy).serve(requests.clone())?;
            for r in &responses {
                let c = cold.iter().find(|c| c.id == r.id).expect("same ids");
                assert_eq!(r.tokens, c.tokens, "prefix cache must not change tokens");
            }
        }
        println!(
            "  prefix cache saved {} of {} prompt tokens{}",
            stats.cached_tokens,
            n_requests * prompt_len,
            if kv_quant == ArenaLayout::F32 {
                " (identical tokens verified)"
            } else {
                " (int8: partial-tail adoptions are bounded, not bitwise)"
            }
        );
    }

    // Show the scheduling win over a baseline on the same workload —
    // same tokens, different batching regime: batched amortizes one
    // weight traversal per tick over round-robin's one per session;
    // continuous packs more sessions into the same arena than
    // fixed-wave worst-case reservations allow.
    let baseline = match policy {
        Policy::Batched { .. } => {
            Some((Policy::RoundRobin { max_active }, "round-robin", "batched"))
        }
        Policy::Continuous { max_active: lanes } => {
            Some((Policy::Batched { batch: lanes }, "fixed-wave batched", "continuous"))
        }
        _ => None,
    };
    if let Some((base_policy, base_label, label)) = baseline {
        let t0 = Instant::now();
        let base = Server::new(&engine, base_policy).serve(requests.clone())?;
        let base_wall = t0.elapsed().as_secs_f64();
        // Scheduler choice never changes tokens — except that with the
        // prefix cache on in int8 mode, WHICH donor block a request
        // adopts (and so which coarser scale a partial tail inherits)
        // can differ between schedules; skip the bitwise check there.
        if kv_quant == ArenaLayout::F32 || !engine.prefix_enabled() {
            for r in &responses {
                let s = base.iter().find(|s| s.id == r.id).expect("same ids");
                assert_eq!(r.tokens, s.tokens, "schedulers must agree token-for-token");
            }
        }
        println!(
            "\n{base_label} baseline: {base_wall:.2}s — {label} speedup {:.2}x \
             (identical tokens)",
            base_wall / wall.max(f64::MIN_POSITIVE)
        );
    }

    // ----------------------------------------------------------------
    // The int8 KV arena trade, at EQUAL arena bytes: size an f32 arena
    // to roughly half the workload's worst-case block demand (so
    // continuous batching has to preempt), give an int8 arena the SAME
    // byte budget, and serve the identical stream through both.
    // ----------------------------------------------------------------
    println!("\n== --kv-quant int8 at equal arena bytes ==");
    let kind = BackendKind::resolve(args.backend())?;
    let geometry =
        CacheLayout::with_block_len(&engine.artifacts.manifest.model, engine.block_len());
    let worst_blocks = geometry.blocks_for_positions(prompt_len + new_tokens);
    let budget = (worst_blocks * max_active.max(2) / 2).max(worst_blocks)
        * geometry.block_bytes(ArenaLayout::F32);
    for mode in [ArenaLayout::F32, ArenaLayout::KvInt8] {
        let blocks = geometry.blocks_for_bytes(budget, mode);
        let e = Engine::load_default_with_arena_mode(kind, engine.block_len(), blocks, mode)?;
        let t0 = Instant::now();
        let out = Server::new(&e, Policy::Continuous { max_active: n_requests.max(1) })
            .serve(requests.clone())?;
        let wall = t0.elapsed().as_secs_f64();
        let s = LatencyStats::from_responses(&out, wall);
        assert!(out.iter().all(|r| r.tokens.len() == prompt_len + new_tokens));
        println!(
            "  kv={:4} {:4} blocks = {:8} bytes | {:2} resident sessions | \
             {:8.1} tok/s | {:3} preemptions",
            mode.name(),
            blocks,
            e.arena_status().total_bytes,
            blocks / worst_blocks.max(1),
            s.tokens_per_s,
            s.evictions,
        );
    }

    // ----------------------------------------------------------------
    // Hardware projection: the same request shape on the simulated edge
    // accelerator (per-request generation with growing context).
    // ----------------------------------------------------------------
    println!("\n== hardware projection of this workload (per request) ==");
    let arch = ArchConfig::paper_45nm();
    for name in ["GPT2-355M", "OPT-6.7B"] {
        let m = models::by_name(name).unwrap();
        let hybrid = token_loop::generate(&arch, &m, Arch::PimLlm, prompt_len, new_tokens);
        let base = token_loop::generate(&arch, &m, Arch::TpuLlm, prompt_len, new_tokens);
        println!(
            "{name:<10} PIM-LLM {:8.2} tok/s, {:7.3} J/req | TPU-LLM {:8.2} tok/s, {:7.3} J/req | speedup {:.1}x",
            hybrid.decode_tokens_per_s(),
            hybrid.total_energy.total_j(),
            base.decode_tokens_per_s(),
            base.total_energy.total_j(),
            base.total_latency_s / hybrid.total_latency_s
        );
    }
    Ok(())
}

/// Speculative-decoding plan for the chosen `--spec-draft`: self/tiny
/// wrap the target's own bundle; oracle records a non-speculative
/// reference run of the same workload first (same kv layout and block
/// geometry — int8 numerics follow both).
fn spec_plan(
    draft: DraftSpec,
    k: usize,
    bundle: &Arc<Artifacts>,
    requests: &[Request],
    block_len: usize,
    kv_quant: ArenaLayout,
) -> Result<Option<SpecPlan>> {
    Ok(match draft {
        DraftSpec::Off => None,
        DraftSpec::SelfModel => Some(SpecPlan::self_draft(bundle, k)?),
        DraftSpec::Tiny => Some(SpecPlan::tiny_draft(bundle, k)?),
        DraftSpec::Oracle => {
            let oracle = Engine::load_default_with_arena_mode(
                BackendKind::Reference,
                block_len,
                0,
                kv_quant,
            )?;
            let recorded = Server::new(&oracle, Policy::Fifo).serve(requests.to_vec())?;
            let book: HashMap<u64, Vec<i32>> =
                recorded.into_iter().map(|r| (r.id, r.tokens)).collect();
            Some(SpecPlan::oracle(book, k)?)
        }
    })
}

/// One shared system prompt over the first half of every request's
/// tokens (the prefix cache's target shape), per-request tail after.
fn workload(vocab: usize, n_requests: usize, prompt_len: usize, new_tokens: usize) -> Vec<Request> {
    let mut rng = Rng::new(7);
    let system: Vec<i32> = (0..prompt_len / 2)
        .map(|_| rng.range(1, vocab - 1) as i32)
        .collect();
    (0..n_requests as u64)
        .map(|id| Request {
            id,
            prompt: system
                .iter()
                .copied()
                .chain((system.len()..prompt_len).map(|_| rng.range(1, vocab - 1) as i32))
                .collect(),
            n_new: new_tokens,
        })
        .collect()
}

/// `--policy sharded`: serve the workload on a W-worker sharded engine,
/// then rerun it on a 1-worker engine at EQUAL total arena capacity and
/// assert byte-identical tokens — the scaling demonstration plus the
/// determinism guarantee in one pass.
#[allow(clippy::too_many_arguments)]
fn sharded_scaling(
    args: &Args,
    workers: usize,
    max_active: usize,
    n_requests: usize,
    prompt_len: usize,
    new_tokens: usize,
    arena_blocks: usize,
    block_len: usize,
    kv_quant: ArenaLayout,
    prefix_cache: bool,
    prefix_cap: usize,
    prefill_chunk: usize,
    spec_draft: DraftSpec,
    spec_k: usize,
) -> Result<()> {
    let kind = BackendKind::resolve(args.backend())?;
    let mut engine =
        ShardedEngine::load_default_mode(kind, block_len, arena_blocks, workers, kv_quant)?;
    let trace_path = args.get("trace").map(std::path::PathBuf::from);
    let metrics = args.flag("metrics")?;
    if trace_path.is_some() || metrics {
        engine.set_obs_enabled(true);
    }
    if prefix_cache {
        engine.enable_prefix_cache(prefix_cap);
    }
    let arena = engine.arena_status();
    println!(
        "engine up: backend={} platform={}, sharded x{} workers ({} lanes each), \
         KV arena {} blocks x {} positions total ({} bytes, kv={}), prefix cache {}",
        engine.backend_name(),
        engine.platform(),
        engine.workers(),
        max_active,
        arena.total_blocks,
        arena.block_len,
        arena.total_bytes,
        engine.arena_mode().name(),
        if engine.prefix_enabled() { "on" } else { "off" }
    );
    let requests = workload(engine.vocab(), n_requests, prompt_len, new_tokens);
    let offsets = vec![0.0; requests.len()];
    let plan = spec_plan(
        spec_draft,
        spec_k,
        engine.shard(0).artifacts(),
        &requests,
        block_len,
        kv_quant,
    )?;

    let t0 = Instant::now();
    let (out, shards) = serve_sharded_stats_lanes(
        &mut engine,
        requests.clone(),
        &offsets,
        max_active,
        0,
        prefill_chunk,
        plan.as_ref(),
    )?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = LatencyStats::from_responses(&out, wall);
    println!(
        "\nserved {} requests ({} tokens) in {:.2}s across {} shards",
        stats.n, stats.total_tokens, wall, workers
    );
    println!("  throughput       : {:8.1} tok/s", stats.tokens_per_s);
    println!(
        "  TTFT mean/p50/p95: {:.3} / {:.3} / {:.3} s",
        stats.mean_ttft_s, stats.p50_ttft_s, stats.p95_ttft_s
    );
    for line in shard_report(&shards).lines() {
        println!("  {line}");
    }
    if let Some(ps) = engine.prefix_stats() {
        println!("  {}", ps.report());
    }
    if let Some(path) = &trace_path {
        let tracks = engine.drain_traces();
        let events: usize = tracks.iter().map(|(_, evs)| evs.len()).sum();
        write_chrome_trace(path, &tracks)?;
        println!(
            "  trace            : {events} events across {} tracks -> {}",
            tracks.len(),
            path.display()
        );
    }
    if metrics {
        print!("{}", engine.metrics_snapshot().render());
    }
    engine.debug_validate()?;

    // 1-worker oracle at the SAME total capacity and per-worker lanes.
    let total = arena.total_blocks;
    let mut one = ShardedEngine::load_default_mode(kind, block_len, total, 1, kv_quant)?;
    if prefix_cache {
        one.enable_prefix_cache(prefix_cap);
    }
    let t0 = Instant::now();
    let (base, _) = serve_sharded_stats(&mut one, requests, &offsets, max_active)?;
    let base_wall = t0.elapsed().as_secs_f64();
    // Worker count never changes tokens — except that with the prefix
    // cache on in int8 mode, per-shard indices can hand different
    // partial-tail scales to the same request; skip bitwise there.
    if kv_quant == ArenaLayout::F32 || !prefix_cache {
        for r in &out {
            let b = base.iter().find(|b| b.id == r.id).expect("same ids");
            assert_eq!(r.tokens, b.tokens, "worker count must not change tokens");
        }
    }
    println!(
        "\n1-worker oracle: {base_wall:.2}s — {workers}-worker speedup {:.2}x \
         (byte-identical tokens verified)",
        base_wall / wall.max(f64::MIN_POSITIVE)
    );
    Ok(())
}
