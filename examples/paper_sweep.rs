//! Paper sweep: regenerate EVERY evaluation artifact of the paper in
//! one run — Fig. 1b, Fig. 4, Fig. 5, Fig. 6, Fig. 7, Fig. 8 and
//! Table III — printing measured values next to the numbers the paper
//! states, exactly like `repro sweep --figure all` but with a summary
//! of paper-vs-measured deviations at the end.
//!
//! Run: `cargo run --release --example paper_sweep`

use pim_llm::analysis::{figures, report};
use pim_llm::config::ArchConfig;

fn main() {
    let arch = ArchConfig::paper_45nm();

    report::print_fig1b(&figures::fig1b(&arch));
    println!();
    report::print_fig4(&figures::fig4(&arch));
    println!();
    let f5 = figures::fig5(&arch);
    report::print_fig5(&f5);
    println!();
    report::print_fig6(&figures::fig6(&arch));
    println!();
    let f7 = figures::fig7(&arch);
    report::print_fig7(&f7);
    println!();
    report::print_fig8(&figures::fig8(&arch));
    println!();
    let t3 = figures::table3(&arch);
    report::print_table3(&t3);

    // ------------------------------------------------ deviation summary
    println!("\n== paper-vs-measured summary ==");
    for r in &f5 {
        if let Some(ps) = r.paper_speedup {
            println!(
                "fig5  {:<12} l={:<5} speedup {:.2}x vs paper {:.2}x ({:+.1}%)",
                r.model,
                r.context,
                r.speedup,
                ps,
                100.0 * (r.speedup / ps - 1.0)
            );
        }
    }
    for r in &f7 {
        if let Some(pg) = r.paper_gain_pct {
            println!(
                "fig7  {:<12} l={:<5} gain {:+.1}% vs paper {:+.1}%",
                r.model, r.context, r.gain_pct, pg
            );
        }
    }
    for r in t3.iter().filter(|r| r.design.contains("ours")) {
        if let (Some(g), Some(pg)) = (r.gops, r.paper_gops) {
            println!(
                "tbl3  {:<12} l={:<5} {:.2} GOPS vs paper {:.2} ({:+.1}%)",
                r.model,
                r.context,
                g,
                pg,
                100.0 * (g / pg - 1.0)
            );
        }
    }
}
