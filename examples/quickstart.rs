//! Quickstart: the 60-second tour of the PIM-LLM stack.
//!
//! 1. Simulate one decode step of OPT-6.7B on the hybrid architecture
//!    and on the TPU-LLM baseline (the paper's headline comparison).
//! 2. Load the tiny 1-bit decoder and generate real tokens, validating
//!    against the golden generation. With AOT artifacts present (`make
//!    artifacts`) that is the JAX-lowered model; without them a
//!    synthetic model runs on the pure-Rust reference backend, so this
//!    example works fully offline.
//!
//! Run: `cargo run --release --example quickstart`

use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, Arch};
use pim_llm::models;
use pim_llm::runtime::{decoder, Engine, TinyDecoder};
use pim_llm::util::error::Result;

fn main() -> Result<()> {
    // ---------------------------------------------------------------
    // Part 1: performance model — one decode step on both architectures.
    // ---------------------------------------------------------------
    let arch = ArchConfig::paper_45nm();
    let model = models::by_name("OPT-6.7B").unwrap();
    let l = 128;

    let hybrid = coordinator::simulate(&arch, &model, l, Arch::PimLlm);
    let baseline = coordinator::simulate(&arch, &model, l, Arch::TpuLlm);
    println!("== {} @ context {l} ==", model.name);
    println!(
        "PIM-LLM : {:8.2} tokens/s  ({:.2} mJ/token)",
        hybrid.metrics().tokens_per_s(),
        1e3 * hybrid.energy.total_j()
    );
    println!(
        "TPU-LLM : {:8.2} tokens/s  ({:.2} mJ/token)",
        baseline.metrics().tokens_per_s(),
        1e3 * baseline.energy.total_j()
    );
    println!(
        "speedup : {:.1}x (paper Fig. 5 reports 79.2x at this point)",
        baseline.latency_s() / hybrid.latency_s()
    );

    // ---------------------------------------------------------------
    // Part 2: functional path — real numerics through PJRT.
    // ---------------------------------------------------------------
    println!("\n== functional tiny-1bit decoder ==");
    let engine = Engine::load_default()?;
    println!(
        "backend {} platform {} | d={} h={} layers={} vocab={}",
        engine.backend_name(),
        engine.platform(),
        engine.artifacts.manifest.model.d,
        engine.artifacts.manifest.model.h,
        engine.artifacts.manifest.model.n_layers,
        engine.vocab()
    );

    // Golden validation: rust must reproduce the jax generation exactly.
    let timing = decoder::validate_golden(&engine)?;
    println!(
        "golden generation reproduced token-for-token (decode {:.1} tok/s, prefill {:.1} tok/s)",
        timing.decode_tokens_per_s(),
        timing.prefill_tokens_per_s()
    );

    // Free-running generation from a custom prompt.
    let mut dec = TinyDecoder::new(&engine)?;
    let prompt = [10, 20, 30, 40];
    dec.generate(&prompt, 12)?;
    println!("prompt {:?} -> {:?}", &prompt, &dec.tokens[prompt.len()..]);
    Ok(())
}
