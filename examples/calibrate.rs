//! Calibration: fit the free 45 nm-class energy constants against the
//! tokens/joule gains the paper states in §IV-C (Fig. 7), and write the
//! result to `configs/calibrated_45nm.toml`.
//!
//! This mirrors what the authors did implicitly when combining Synopsys
//! DC numbers (TPU) with MNSIM 2.0 output (PIM): a handful of
//! technology constants determine every energy figure. We fit five of
//! them by coordinate descent on the log-ratio error over the paper's
//! stated anchor points.
//!
//! NOTE (see EXPERIMENTS.md §Fig.7): the paper's full anchor set is not
//! jointly satisfiable by ANY time-invariant component model — the
//! stated gains grow with context length although both architectures
//! execute identical attention ops. The fit therefore weights the
//! model-size crossover points (all at l=128) higher and accepts
//! residuals on the long-context points.
//!
//! Run: `cargo run --release --example calibrate`

use pim_llm::config::ArchConfig;
use pim_llm::coordinator::{self, Arch};
use pim_llm::models;
use pim_llm::util::error::Result;

/// (model, context, paper tokens/J gain of PIM over TPU in %, weight)
const ANCHORS: &[(&str, usize, f64, f64)] = &[
    ("GPT2-355M", 128, -25.2, 3.0),
    ("OPT-1.3B", 128, 0.96, 3.0),
    ("OPT-6.7B", 128, 12.49, 3.0),
    ("GPT2-355M", 2048, 17.95, 1.0),
    ("OPT-6.7B", 2048, 22.79, 1.0),
    ("GPT2-355M", 4096, 70.58, 1.0),
    ("OPT-6.7B", 4096, 33.7, 1.0),
];

/// Absolute-scale anchors from Table III: (model, context, GOPS/W).
/// Without these the fit is scale-free (Fig. 7 is all ratios) and the
/// absolute energy axis floats.
const GOPS_W_ANCHORS: &[(&str, usize, f64)] = &[
    ("GPT2-Small", 1024, 487.4),
    ("GPT2-Medium", 4096, 1026.0),
    ("OPT-6.7B", 1024, 1134.14),
    ("OPT-6.7B", 4096, 1262.72),
];

fn loss(arch: &ArchConfig) -> f64 {
    let mut total = 0.0;
    for &(name, l, paper_gain, w) in ANCHORS {
        let m = models::by_name(name).unwrap();
        let p = coordinator::simulate(arch, &m, l, Arch::PimLlm);
        let t = coordinator::simulate(arch, &m, l, Arch::TpuLlm);
        let ratio = t.energy.total_j() / p.energy.total_j();
        let want = 1.0 + paper_gain / 100.0;
        let e = (ratio / want).ln();
        total += w * e * e;
    }
    for &(name, l, paper_gpw) in GOPS_W_ANCHORS {
        let m = models::by_name(name).unwrap();
        let p = coordinator::simulate(arch, &m, l, Arch::PimLlm);
        let e = (p.metrics().gops_per_w() / paper_gpw).ln();
        total += e * e;
    }
    total
}

/// The five fitted knobs, as (name, getter-index) — applied via apply().
const KNOBS: &[&str] = &[
    "pim.xbar_mac_energy_j",
    "pim.fixed_token_energy_j",
    "peripheral.energy_per_layer_j",
    "lpddr.energy_per_byte_j",
    "tpu.static_power_w",
];

fn get(arch: &ArchConfig, knob: &str) -> f64 {
    match knob {
        "pim.xbar_mac_energy_j" => arch.pim.xbar_mac_energy_j,
        "pim.fixed_token_energy_j" => arch.pim.fixed_token_energy_j,
        "peripheral.energy_per_layer_j" => arch.peripheral.energy_per_layer_j,
        "lpddr.energy_per_byte_j" => arch.lpddr.energy_per_byte_j,
        "tpu.static_power_w" => arch.tpu.static_power_w,
        _ => unreachable!(),
    }
}

fn set(arch: &mut ArchConfig, knob: &str, v: f64) {
    match knob {
        "pim.xbar_mac_energy_j" => arch.pim.xbar_mac_energy_j = v,
        "pim.fixed_token_energy_j" => arch.pim.fixed_token_energy_j = v,
        "peripheral.energy_per_layer_j" => arch.peripheral.energy_per_layer_j = v,
        "lpddr.energy_per_byte_j" => arch.lpddr.energy_per_byte_j = v,
        "tpu.static_power_w" => arch.tpu.static_power_w = v,
        _ => unreachable!(),
    }
}

fn main() -> Result<()> {
    let mut arch = ArchConfig::paper_45nm();
    let mut best = loss(&arch);
    println!("initial loss: {best:.4}");

    // Coordinate descent: multiplicative steps, shrinking schedule.
    let mut step = 1.6f64;
    for round in 0..60 {
        let mut improved = false;
        for knob in KNOBS {
            let cur = get(&arch, knob);
            for trial in [cur * step, cur / step] {
                let mut cand = arch.clone();
                set(&mut cand, knob, trial);
                let l = loss(&cand);
                if l < best {
                    best = l;
                    arch = cand;
                    improved = true;
                }
            }
        }
        if !improved {
            step = step.sqrt();
            if step < 1.005 {
                println!("converged after {round} rounds");
                break;
            }
        }
    }
    println!("final loss: {best:.4}");

    println!("\nfitted constants:");
    for knob in KNOBS {
        println!("  {knob:<32} = {:.4e}", get(&arch, knob));
    }

    println!("\nanchor fit (paper vs calibrated):");
    for &(name, l, paper_gain, _) in ANCHORS {
        let m = models::by_name(name).unwrap();
        let p = coordinator::simulate(&arch, &m, l, Arch::PimLlm);
        let t = coordinator::simulate(&arch, &m, l, Arch::TpuLlm);
        let gain = 100.0
            * (t.energy.total_j() / p.energy.total_j() - 1.0);
        println!("  {name:<12} l={l:<5} paper {paper_gain:+7.2}%  fitted {gain:+7.2}%");
    }

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/calibrated_45nm.toml");
    arch.to_toml_file(&out)?;
    println!("\nwrote {}", out.display());

    // Sanity: the calibrated config must not break the latency-side
    // reproduction (Fig. 5 speedups are energy-independent, but assert
    // anyway so a bad fit cannot silently land in configs/).
    let s = coordinator::speedup(&arch, &models::by_name("OPT-6.7B").unwrap(), 128);
    assert!((s - 79.2).abs() / 79.2 < 0.15, "fig5 regression: {s}");
    println!("fig5 speedup check still OK ({s:.1}x)");
    Ok(())
}
