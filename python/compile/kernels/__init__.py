"""PIM-LLM L1 Pallas kernels.

``bitlinear`` — W1A8 ternary projection matmul (the PIM-crossbar op).
``qmatmul``   — W8A8 attention matmul (the systolic-array op).
``ref``       — pure-jnp correctness oracle for both.
"""

from . import ref  # noqa: F401
from .bitlinear import bitlinear, bitlinear_matmul  # noqa: F401
from .qmatmul import qmatmul, qmatmul_int  # noqa: F401
