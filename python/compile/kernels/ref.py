"""Pure-jnp reference oracle for the PIM-LLM kernels.

This module is the single source of truth for the numerics of the 1-bit
LLM compute path:

  * ``weight_quant_ternary``  — BitNet-b1.58-style ternary weight
    quantization (the values that would be programmed into the RRAM
    crossbar's differential device pairs).
  * ``act_quant_int8``        — absmax 8-bit activation quantization (the
    values the crossbar DACs drive / the 8-bit ADCs read back).
  * ``int_matmul_ref``        — exact integer matmul on f32 carriers; the
    oracle both Pallas kernels are tested against.
  * ``bitlinear_ref``         — full W1A8 projection (quantize → matmul →
    rescale), what one PIM bank computes for a projection layer.
  * ``qmatmul_ref``           — full W8A8 activation-to-activation matmul,
    what the systolic array computes inside an attention head.

All quantized integer values are carried in float32.  This is exact for
|v| < 2**24 and the largest magnitude we ever produce is bounded by
k_max * 127 * 127 (< 2**24 for k <= 1040 at int8*int8 and far below it
for ternary weights), so the carrier introduces no rounding.  Where an
inner dimension could overflow the exact-f32 window we tile the reduction
(see ``bitlinear.py``) — the tiny AOT model (k <= 1024) is always exact.
"""

from __future__ import annotations

import jax.numpy as jnp

# Quantization ranges for W8A8 / W1A8 paths.
INT8_QMAX = 127.0
INT8_QMIN = -128.0
# Inner-dim bound under which int8*int8 accumulation in f32 is exact.
EXACT_F32_K_LIMIT = 1040


def weight_quant_ternary(w: jnp.ndarray, eps: float = 1e-5):
    """BitNet b1.58 ternary weight quantization.

    scale = mean(|W|); W_q = clip(round(W / scale), -1, 1).

    Returns ``(w_q, scale)`` where ``w_q`` contains exactly {-1, 0, +1}
    (as f32) and ``w ≈ w_q * scale``.
    """
    scale = jnp.mean(jnp.abs(w))
    scale = jnp.maximum(scale, eps)
    w_q = jnp.clip(jnp.round(w / scale), -1.0, 1.0)
    return w_q, scale


def act_quant_int8(x: jnp.ndarray, eps: float = 1e-5):
    """Absmax per-tensor symmetric int8 quantization.

    scale = 127 / max(|x|); x_q = clip(round(x * scale), -128, 127).

    Returns ``(x_q, scale)`` with ``x ≈ x_q / scale``.
    """
    absmax = jnp.max(jnp.abs(x))
    scale = INT8_QMAX / jnp.maximum(absmax, eps)
    x_q = jnp.clip(jnp.round(x * scale), INT8_QMIN, INT8_QMAX)
    return x_q, scale


def int_matmul_ref(a_q: jnp.ndarray, b_q: jnp.ndarray) -> jnp.ndarray:
    """Exact integer matmul oracle: (m,k) @ (k,n) on f32 carriers."""
    return jnp.matmul(a_q, b_q, preferred_element_type=jnp.float32)


def bitlinear_ref(x: jnp.ndarray, w_q: jnp.ndarray, w_scale: jnp.ndarray):
    """W1A8 projection: y ≈ x @ (w_q * w_scale) with 8-bit activations.

    ``x``: (m, k) float activations; ``w_q``: (k, n) ternary; ``w_scale``:
    scalar.  Mirrors what the PIM crossbar computes: the DAC drives the
    int8 activation bit-serially, the crossbar multiplies by the ternary
    conductance pairs, the ADC digitizes, and the postprocessing unit
    applies the combined dequantization scale.
    """
    x_q, x_scale = act_quant_int8(x)
    acc = int_matmul_ref(x_q, w_q)
    return acc * (w_scale / x_scale)


def qmatmul_ref(a: jnp.ndarray, b: jnp.ndarray):
    """W8A8 activation-to-activation matmul: y ≈ a @ b, both int8-quantized.

    This is the attention-head operation (Q·Kᵀ and Score·V) that PIM-LLM
    keeps on the digital systolic array: both operands change every token,
    so neither can live in RRAM.
    """
    a_q, a_scale = act_quant_int8(a)
    b_q, b_scale = act_quant_int8(b)
    acc = int_matmul_ref(a_q, b_q)
    return acc / (a_scale * b_scale)
