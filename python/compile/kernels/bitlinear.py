"""Pallas W1A8 bitlinear kernel — the projection-layer hot spot.

This is the operation PIM-LLM maps onto analog RRAM crossbars: a ternary
weight matrix (programmed once into differential memristor pairs) times an
8-bit-quantized activation vector.  On a TPU we cannot build a crossbar,
so we express the *same insight* for the MXU:

  * **Weight-stationary schedule.**  The crossbar's defining property is
    that weights never move.  Our BlockSpec iterates the grid with the
    output-column axis outermost and the reduction axis innermost, so a
    ternary weight tile stays resident in VMEM across the activation
    stream exactly like a crossbar column stays programmed across input
    vectors.
  * **Minimal-traffic operands.**  The ternary weights are carried in the
    narrowest dtype the interchange supports; on real TPU hardware this
    tile would be int8 (1.58 effective bits after packing), cutting HBM
    traffic 16x vs bf16 — decode MVMs are bandwidth-bound, so this is the
    whole speedup, mirroring the paper's "weights live in the crossbar"
    argument.
  * **MXU-shaped tiles.**  Default blocks are (128, 512, 128): the
    128x128 output tile matches the MXU systolic array; the 512-deep
    reduction amortizes pipeline fill, analogous to the paper's 256-row
    crossbar amortizing DAC setup.

The kernel computes the *integer* matmul ``acc = x_q @ w_q`` on f32
carriers (exact; see ref.py).  Activation quantization and the combined
dequantization scale are applied by the caller (``bitlinear``), matching
the paper's split: DAC/crossbar/ADC do the integer MVM, the digital
postprocessing unit applies scales.

Kernels run with ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# MXU-shaped defaults; shrunk automatically for small operands.
DEFAULT_BM = 128
DEFAULT_BK = 512
DEFAULT_BN = 128


def _pad_to(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to (m, n)."""
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _block_sizes(m: int, k: int, n: int, bm: int, bk: int, bn: int):
    """Clamp block sizes to the (padded) operand sizes."""
    return min(bm, m), min(bk, k), min(bn, n)


def _bitlinear_kernel(x_ref, w_ref, o_ref, *, nsteps_k: int):
    """Grid = (n_blocks, m_blocks, k_blocks); k innermost (stationary
    weight tile per (n, m) is revisited only after a full k sweep — the
    weight-stationary order puts n outermost so each weight column block
    services the whole activation stream before moving on)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def bitlinear_matmul(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """Integer matmul ``x_q @ w_q`` via the weight-stationary Pallas kernel.

    ``x_q``: (m, k) int8-valued f32; ``w_q``: (k, n) ternary-valued f32.
    Operands are zero-padded to block multiples (zeros contribute nothing
    to the accumulation) and the result is sliced back.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bk, bn = _block_sizes(m, k, n, bm, bk, bn)
    mp = pl.cdiv(m, bm) * bm
    kp = pl.cdiv(k, bk) * bk
    np_ = pl.cdiv(n, bn) * bn
    x_p = _pad_to(x_q, mp, kp)
    w_p = _pad_to(w_q, kp, np_)
    grid = (np_ // bn, mp // bm, kp // bk)

    out = pl.pallas_call(
        functools.partial(_bitlinear_kernel, nsteps_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ni, mi, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda ni, mi, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda ni, mi, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x_p, w_p)
    return out[:m, :n]


def bitlinear(
    x: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """Full W1A8 projection: absmax-int8 the activations, ternary matmul
    on the Pallas kernel, then apply the combined dequantization scale.

    Matches ``ref.bitlinear_ref`` exactly (integer path is exact)."""
    x_q, x_scale = ref.act_quant_int8(x)
    acc = bitlinear_matmul(x_q, w_q, bm=bm, bk=bk, bn=bn)
    return acc * (w_scale / x_scale)
