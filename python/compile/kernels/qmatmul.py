"""Pallas W8A8 quantized matmul kernel — the attention-head hot spot.

This is the operation PIM-LLM keeps OFF the crossbars and on the digital
32x32 output-stationary systolic array: activation-to-activation matmuls
(Q.K^T and Score.V) whose *both* operands change every generated token,
so neither can be programmed into RRAM (write energy + endurance).

The schedule mirrors the paper's output-stationary dataflow choice
(Fig. 4): the reduction axis is innermost and the partial sum stays
resident in the output VMEM tile across the whole k sweep — exactly the
OS systolic array keeping partial sums stationary in the PEs while
weights and inputs stream past.  Grid order (m, n, k) with k innermost;
the output tile is touched by consecutive grid steps only.

Integer matmul on f32 carriers; quantization and dequantization scales
are applied by the caller (``qmatmul``), matching the split between the
8-bit MAC array and its peripheral scale logic.  ``interpret=True`` —
see bitlinear.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .bitlinear import _pad_to, _block_sizes

# Attention shapes are (l x d/h) with small d/h; narrower default blocks.
DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128


def _qmatmul_kernel(a_ref, b_ref, o_ref):
    """Grid = (m_blocks, n_blocks, k_blocks); output-stationary: the
    (m, n) output tile accumulates in place across the innermost k loop."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def qmatmul_int(
    a_q: jnp.ndarray,
    b_q: jnp.ndarray,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """Integer matmul ``a_q @ b_q`` via the output-stationary Pallas kernel.

    Both operands are int8-valued f32 carriers; exact for k <= 1040
    (ref.EXACT_F32_K_LIMIT)."""
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bk, bn = _block_sizes(m, k, n, bm, bk, bn)
    mp = pl.cdiv(m, bm) * bm
    kp = pl.cdiv(k, bk) * bk
    np_ = pl.cdiv(n, bn) * bn
    a_p = _pad_to(a_q, mp, kp)
    b_p = _pad_to(b_q, kp, np_)
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        _qmatmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


def qmatmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """Full W8A8 matmul: int8-quantize both operands, integer matmul on
    the Pallas kernel, dequantize.  Matches ``ref.qmatmul_ref`` exactly."""
    a_q, a_scale = ref.act_quant_int8(a)
    b_q, b_scale = ref.act_quant_int8(b)
    acc = qmatmul_int(a_q, b_q, bm=bm, bk=bk, bn=bn)
    return acc / (a_scale * b_scale)
