"""AOT compile path: lower the 1-bit decoder to HLO text + dump weights.

Emits into ``artifacts/``:

  * ``decode_step.hlo.txt`` — one autoregressive step of the tiny 1-bit
    decoder (all params + caches + token + pos as arguments), as HLO
    *text*.  Text, NOT ``.serialize()``: jax >= 0.5 emits HloModuleProto
    with 64-bit instruction ids which xla_extension 0.5.1 (the version
    behind the ``xla`` rust crate) rejects; the text parser reassigns ids
    and round-trips cleanly (see /opt/xla-example/README.md).
  * ``model.hlo.txt`` — alias of decode_step (the Makefile's stamp file).
  * ``weights.bin`` — all parameters, f32 little-endian, concatenated in
    canonical ``model.param_names`` order.
  * ``manifest.json`` — model config + per-parameter name/shape/offset +
    argument layout of the HLO entry (so the Rust loader is self-
    describing).
  * ``golden.json`` — greedy generation from a fixed prompt + the first
    logits vector, produced by running the SAME jax graph; the Rust
    runtime must reproduce these tokens exactly.

Python runs only here, at build time; the Rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .model import TINY, ModelConfig

GOLDEN_PROMPT = [1, 7, 42, 9]
GOLDEN_NEW_TOKENS = 12


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode_step(cfg: ModelConfig) -> str:
    """Lower one decode step with example (shape-only) arguments."""
    shapes = model.param_shapes(cfg)
    flat_specs = tuple(
        jax.ShapeDtypeStruct(shapes[n], jnp.float32)
        for n in model.param_names(cfg)
    )
    cache_spec = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.h, cfg.max_ctx, cfg.d_head), jnp.float32
    )
    tok_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(flat_params, k, v, token_id, pos):
        return model.decode_step(cfg, flat_params, k, v, token_id, pos)

    lowered = jax.jit(fn).lower(
        flat_specs, cache_spec, cache_spec, tok_spec, tok_spec
    )
    return to_hlo_text(lowered)


def dump_weights(cfg: ModelConfig, params, outdir: pathlib.Path) -> dict:
    """weights.bin + per-parameter manifest entries (offsets in floats)."""
    entries = []
    offset = 0
    blobs = []
    for name in model.param_names(cfg):
        arr = np.asarray(params[name], dtype=np.float32)
        entries.append(
            {"name": name, "shape": list(arr.shape), "offset": offset,
             "numel": int(arr.size)}
        )
        blobs.append(arr.reshape(-1))
        offset += int(arr.size)
    flat = np.concatenate(blobs) if blobs else np.zeros(0, np.float32)
    (outdir / "weights.bin").write_bytes(flat.astype("<f4").tobytes())
    return {"params": entries, "total_floats": int(offset)}


def dump_golden(cfg: ModelConfig, params, outdir: pathlib.Path) -> None:
    """Golden greedy generation + first-step logits for Rust validation."""
    tokens = model.generate(cfg, params, GOLDEN_PROMPT, GOLDEN_NEW_TOKENS)
    flat = model.flatten_params(cfg, params)
    k, v = model.empty_caches(cfg)
    logits, _, _ = model.decode_step(
        cfg, flat, k, v, jnp.int32(GOLDEN_PROMPT[0]), jnp.int32(0)
    )
    golden = {
        "prompt": GOLDEN_PROMPT,
        "n_new": GOLDEN_NEW_TOKENS,
        "tokens": [int(t) for t in tokens],
        "first_logits_prefix": [float(x) for x in np.asarray(logits)[:8]],
        "first_logits_l2": float(np.linalg.norm(np.asarray(logits))),
    }
    (outdir / "golden.json").write_text(json.dumps(golden, indent=2))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="stamp-file path (Makefile target); artifacts land "
                         "in its directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    outdir = out.parent
    outdir.mkdir(parents=True, exist_ok=True)

    cfg = TINY
    params = model.init_params(cfg, seed=args.seed)

    hlo = lower_decode_step(cfg)
    (outdir / "decode_step.hlo.txt").write_text(hlo)
    out.write_text(hlo)  # model.hlo.txt alias / make stamp
    print(f"decode_step HLO: {len(hlo)} chars")

    manifest = {
        "model": dataclasses.asdict(cfg),
        "seed": args.seed,
        "entry": "decode_step",
        # Argument layout of the lowered entry: params... then caches,
        # token, pos.  return_tuple=True => single 3-tuple output.
        "arg_order": model.param_names(cfg)
        + ["k_caches", "v_caches", "token_id", "pos"],
        "outputs": ["logits", "new_k_caches", "new_v_caches"],
    }
    manifest.update(dump_weights(cfg, params, outdir))
    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"weights: {manifest['total_floats']} f32 "
          f"({manifest['total_floats'] * 4 / 1e6:.1f} MB)")

    dump_golden(cfg, params, outdir)
    print("golden.json written")


if __name__ == "__main__":
    main()
