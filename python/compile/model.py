"""L2: functional 1-bit decoder-only LLM (BitNet-b1.58 style) in JAX.

This is the compute graph PIM-LLM accelerates, with the paper's exact
precision split:

  * **Projection layers** (W_Q, W_K, W_V, W_X, FF in/out, LM head):
    ternary weights + int8 activations (W1A8) -> ``kernels.bitlinear``
    (the PIM-crossbar path).
  * **Attention heads** (Q.K^T and Score.V): both operands int8 (W8A8)
    -> ``kernels.qmatmul`` (the systolic-array path).
  * Nonlinearities (RMSNorm, softmax, GELU) stay in f32, mirroring the
    paper's dedicated nonlinear functional units (ConSmax etc.).

The model is *functional*: parameters and KV caches are explicit inputs,
updated caches are explicit outputs, so the whole decode step lowers to
one HLO module the Rust runtime executes via PJRT.  Shapes are static
(max_ctx); the current position is a traced i32 scalar used for cache
update and causal masking.

Weights are pre-quantized offline (aot.py): each projection is stored as
its ternary matrix (f32 carrier holding {-1,0,1}) plus a scalar scale —
exactly the data that would be programmed into the crossbars.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import bitlinear, qmatmul
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of the decoder (paper Table II shape, tiny scale)."""

    vocab: int = 256
    d: int = 256          # embedding dim
    h: int = 4            # attention heads
    d_ff: int = 1024      # FF intermediate dim
    n_layers: int = 2     # decoder blocks
    max_ctx: int = 128    # static KV-cache length
    eps: float = 1e-5

    @property
    def d_head(self) -> int:
        return self.d // self.h


TINY = ModelConfig()

# Flat parameter ordering (names) for a given config; the AOT manifest and
# the Rust loader both follow this order exactly.
_PER_LAYER = [
    "ln1_gamma",
    "wq", "wq_scale",
    "wk", "wk_scale",
    "wv", "wv_scale",
    "wx", "wx_scale",
    "ln2_gamma",
    "w_in", "w_in_scale",
    "w_out", "w_out_scale",
]
_GLOBAL = ["embedding", "lnf_gamma", "w_head", "w_head_scale"]


def param_names(cfg: ModelConfig) -> List[str]:
    """Flat parameter order: per-layer blocks then globals."""
    names: List[str] = []
    for i in range(cfg.n_layers):
        names.extend(f"layer{i}.{n}" for n in _PER_LAYER)
    names.extend(_GLOBAL)
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Shape of every parameter in ``param_names`` order."""
    d, dff, v = cfg.d, cfg.d_ff, cfg.vocab
    per = {
        "ln1_gamma": (d,),
        "wq": (d, d), "wq_scale": (),
        "wk": (d, d), "wk_scale": (),
        "wv": (d, d), "wv_scale": (),
        "wx": (d, d), "wx_scale": (),
        "ln2_gamma": (d,),
        "w_in": (d, dff), "w_in_scale": (),
        "w_out": (dff, d), "w_out_scale": (),
    }
    shapes: Dict[str, Tuple[int, ...]] = {}
    for i in range(cfg.n_layers):
        for n, s in per.items():
            shapes[f"layer{i}.{n}"] = s
    shapes["embedding"] = (v, d)
    shapes["lnf_gamma"] = (d,)
    shapes["w_head"] = (d, v)
    shapes["w_head_scale"] = ()
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Random master weights -> pre-quantized inference parameters.

    Projection matrices are stored ternary (+ scale); norms/embedding stay
    f32, matching a deployed 1-bit checkpoint.
    """
    key = jax.random.PRNGKey(seed)
    shapes = param_shapes(cfg)
    params: Dict[str, jnp.ndarray] = {}
    for name in param_names(cfg):
        shape = shapes[name]
        if name.endswith("_scale"):
            continue  # produced alongside its matrix below
        base = name.split(".")[-1]
        key, sub = jax.random.split(key)
        if base in ("ln1_gamma", "ln2_gamma", "lnf_gamma"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif base == "embedding":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            # Projection: sample a master weight, quantize to ternary.
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            w_q, scale = ref.weight_quant_ternary(w)
            params[name] = w_q
            params[name + "_scale"] = jnp.asarray(scale, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]):
    """Dict -> tuple in canonical order (the AOT argument order)."""
    return tuple(params[n] for n in param_names(cfg))


def unflatten_params(cfg: ModelConfig, flat) -> Dict[str, jnp.ndarray]:
    return dict(zip(param_names(cfg), flat))


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm — the paper's LayerNorm-class op, done in the digital
    postprocessing units / nonlinear functional unit."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gamma


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x, approximate=True)


def _attention(
    cfg: ModelConfig,
    q: jnp.ndarray,        # (1, d)
    k_cache: jnp.ndarray,  # (h, max_ctx, d_head) — this layer, updated
    v_cache: jnp.ndarray,  # (h, max_ctx, d_head)
    pos: jnp.ndarray,      # scalar i32, index of the current token
) -> jnp.ndarray:
    """Single-token multi-head attention over the (already updated) cache.

    Both matmuls run through the W8A8 qmatmul kernel — the systolic-array
    side of the hybrid split.  Causal masking keeps only cache slots
    [0, pos].
    """
    dh, h, t = cfg.d_head, cfg.h, cfg.max_ctx
    q_heads = q.reshape(h, dh)  # (h, dh)
    idx = jnp.arange(t)
    valid = (idx <= pos)[None, :]  # (1, t)

    # The hardware fetches only the l valid K/V rows from LPDDR into the
    # TPU's weight memory; slots beyond `pos` never reach the systolic
    # array.  Zeroing them here mirrors that AND keeps the absmax int8
    # scale independent of stale cache contents (otherwise garbage in
    # future slots would perturb the quantization of valid entries).
    k_cache = jnp.where(valid[:, :, None], k_cache, 0.0)
    v_cache = jnp.where(valid[:, :, None], v_cache, 0.0)

    outs = []
    for head in range(h):
        # Score = q . K^T : (1, dh) @ (dh, t)  — W8A8 on the TPU side.
        scores = qmatmul(q_heads[head][None, :], k_cache[head].T)  # (1, t)
        scores = scores / jnp.sqrt(jnp.float32(dh))
        scores = jnp.where(valid, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        # Out = probs . V : (1, t) @ (t, dh) — W8A8 on the TPU side.
        outs.append(qmatmul(probs, v_cache[head]))  # (1, dh)
    return jnp.concatenate(outs, axis=-1)  # (1, d)


def _decoder_block(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    layer: int,
    x: jnp.ndarray,        # (1, d)
    k_cache: jnp.ndarray,  # (h, max_ctx, d_head)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,
):
    """One decoder block: pre-norm attention + pre-norm FF, all
    projections W1A8 (the PIM side), attention W8A8 (the TPU side)."""
    L = f"layer{layer}."
    dh, h = cfg.d_head, cfg.h

    # --- attention sub-block ------------------------------------------
    xn = rms_norm(x, p[L + "ln1_gamma"], cfg.eps)
    q = bitlinear(xn, p[L + "wq"], p[L + "wq_scale"])  # (1, d)
    k = bitlinear(xn, p[L + "wk"], p[L + "wk_scale"])
    v = bitlinear(xn, p[L + "wv"], p[L + "wv_scale"])

    # Write this token's K/V into the cache at `pos` (LPDDR-side K/V
    # concat in the paper; never touches RRAM).
    k_heads = k.reshape(h, 1, dh)
    v_heads = v.reshape(h, 1, dh)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_heads, (0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_heads, (0, pos, 0))

    att = _attention(cfg, q, k_cache, v_cache, pos)
    att = bitlinear(att, p[L + "wx"], p[L + "wx_scale"])
    x = x + att

    # --- feed-forward sub-block ---------------------------------------
    xn = rms_norm(x, p[L + "ln2_gamma"], cfg.eps)
    ff = bitlinear(xn, p[L + "w_in"], p[L + "w_in_scale"])
    ff = gelu(ff)
    ff = bitlinear(ff, p[L + "w_out"], p[L + "w_out_scale"])
    x = x + ff
    return x, k_cache, v_cache


def decode_step(
    cfg: ModelConfig,
    flat_params: tuple,
    k_caches: jnp.ndarray,  # (n_layers, h, max_ctx, d_head)
    v_caches: jnp.ndarray,
    token_id: jnp.ndarray,  # scalar i32
    pos: jnp.ndarray,       # scalar i32
):
    """One autoregressive step: embed token, run all decoder blocks,
    return (logits, new_k_caches, new_v_caches).

    This is THE function lowered to ``artifacts/decode_step.hlo.txt`` and
    executed by the Rust coordinator for every generated token.
    """
    p = unflatten_params(cfg, flat_params)
    x = p["embedding"][token_id][None, :]  # (1, d)

    new_k, new_v = [], []
    for layer in range(cfg.n_layers):
        x, kc, vc = _decoder_block(
            cfg, p, layer, x, k_caches[layer], v_caches[layer], pos
        )
        new_k.append(kc)
        new_v.append(vc)

    x = rms_norm(x, p["lnf_gamma"], cfg.eps)
    logits = bitlinear(x, p["w_head"], p["w_head_scale"])  # (1, vocab)
    return (
        logits[0],
        jnp.stack(new_k, axis=0),
        jnp.stack(new_v, axis=0),
    )


def empty_caches(cfg: ModelConfig):
    shape = (cfg.n_layers, cfg.h, cfg.max_ctx, cfg.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def generate(
    cfg: ModelConfig,
    params: Dict[str, jnp.ndarray],
    prompt: List[int],
    n_new: int,
) -> List[int]:
    """Pure-python reference generation loop (greedy).  Used to produce
    the golden token sequence the Rust runtime is validated against."""
    flat = flatten_params(cfg, params)
    k, v = empty_caches(cfg)
    tokens = list(prompt)
    logits = None
    for pos, tok in enumerate(tokens):
        logits, k, v = decode_step(
            cfg, flat, k, v, jnp.int32(tok), jnp.int32(pos)
        )
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits))
        tokens.append(nxt)
        logits, k, v = decode_step(
            cfg, flat, k, v, jnp.int32(nxt), jnp.int32(len(tokens) - 1)
        )
    return tokens
