# pytest: L2 model — shapes, causal masking, KV-cache semantics,
# determinism, and quantization plumbing of the 1-bit decoder.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import ModelConfig

# Smaller-than-TINY config so interpret-mode pallas stays fast in CI.
CFG = ModelConfig(vocab=32, d=32, h=2, d_ff=64, n_layers=2, max_ctx=16)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def flat(params):
    return model.flatten_params(CFG, params)


def _step(flat, k, v, tok, pos):
    return model.decode_step(
        CFG, flat, k, v, jnp.int32(tok), jnp.int32(pos)
    )


# ------------------------------------------------------------- structure
def test_param_names_order_stable():
    names = model.param_names(CFG)
    assert names[0] == "layer0.ln1_gamma"
    assert names[-1] == "w_head_scale"
    assert len(names) == CFG.n_layers * 14 + 4
    assert len(set(names)) == len(names)


def test_param_shapes_cover_all_names():
    names = model.param_names(CFG)
    shapes = model.param_shapes(CFG)
    assert set(names) == set(shapes)


def test_init_params_projections_are_ternary(params):
    for name, arr in params.items():
        base = name.split(".")[-1]
        if base in ("wq", "wk", "wv", "wx", "w_in", "w_out", "w_head"):
            vals = set(np.unique(np.asarray(arr)).tolist())
            assert vals <= {-1.0, 0.0, 1.0}, name
            # scale exists and is positive
            s = params[name + "_scale"]
            assert float(s) > 0


def test_flatten_unflatten_roundtrip(params, flat):
    back = model.unflatten_params(CFG, flat)
    assert set(back) == set(params)
    for n in params:
        np.testing.assert_array_equal(np.asarray(back[n]), np.asarray(params[n]))


# ----------------------------------------------------------- decode step
def test_decode_step_shapes(flat):
    k, v = model.empty_caches(CFG)
    logits, nk, nv = _step(flat, k, v, 3, 0)
    assert logits.shape == (CFG.vocab,)
    assert nk.shape == (CFG.n_layers, CFG.h, CFG.max_ctx, CFG.d_head)
    assert nv.shape == nk.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_decode_step_writes_cache_at_pos(flat):
    k, v = model.empty_caches(CFG)
    pos = 5
    _, nk, nv = _step(flat, k, v, 3, pos)
    nk, nv = np.asarray(nk), np.asarray(nv)
    # only column `pos` may be non-zero
    mask = np.zeros(nk.shape, bool)
    mask[:, :, pos, :] = True
    assert np.any(nk[mask] != 0)
    assert np.all(nk[~mask] == 0)
    assert np.all(nv[~mask] == 0)


def test_decode_step_deterministic(flat):
    k, v = model.empty_caches(CFG)
    l1, _, _ = _step(flat, k, v, 7, 0)
    l2, _, _ = _step(flat, k, v, 7, 0)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_causal_mask_future_cache_ignored(flat):
    """Garbage in cache slots beyond `pos` must not change the logits."""
    k, v = model.empty_caches(CFG)
    logits_a, nk, nv = _step(flat, k, v, 3, 0)
    rng = np.random.default_rng(0)
    k_dirty = np.asarray(k).copy()
    v_dirty = np.asarray(v).copy()
    k_dirty[:, :, 1:, :] = rng.normal(size=k_dirty[:, :, 1:, :].shape)
    v_dirty[:, :, 1:, :] = rng.normal(size=v_dirty[:, :, 1:, :].shape)
    logits_b, _, _ = _step(
        flat, jnp.asarray(k_dirty, jnp.float32),
        jnp.asarray(v_dirty, jnp.float32), 3, 0
    )
    np.testing.assert_array_equal(np.asarray(logits_a), np.asarray(logits_b))


def test_past_cache_does_affect_logits(flat):
    """Conversely, slots <= pos must matter (attention actually reads)."""
    k, v = model.empty_caches(CFG)
    _, k1, v1 = _step(flat, k, v, 3, 0)
    logits_a, _, _ = _step(flat, k1, v1, 5, 1)
    rng = np.random.default_rng(1)
    k_dirty = np.asarray(k1).copy()
    k_dirty[:, :, 0, :] += rng.normal(size=k_dirty[:, :, 0, :].shape)
    logits_b, _, _ = _step(flat, jnp.asarray(k_dirty, jnp.float32), v1, 5, 1)
    assert np.any(np.asarray(logits_a) != np.asarray(logits_b))


def test_token_identity_changes_logits(flat):
    k, v = model.empty_caches(CFG)
    la, _, _ = _step(flat, k, v, 1, 0)
    lb, _, _ = _step(flat, k, v, 2, 0)
    assert np.any(np.asarray(la) != np.asarray(lb))


# -------------------------------------------------------------- generate
def test_generate_golden_reproducible(params):
    t1 = model.generate(CFG, params, [1, 2, 3], 4)
    t2 = model.generate(CFG, params, [1, 2, 3], 4)
    assert t1 == t2
    assert len(t1) == 7
    assert t1[:3] == [1, 2, 3]
    assert all(0 <= t < CFG.vocab for t in t1)


def test_generate_prefix_property(params):
    """Generating k then k+1 tokens agrees on the shared prefix (greedy)."""
    a = model.generate(CFG, params, [4, 5], 2)
    b = model.generate(CFG, params, [4, 5], 4)
    assert b[: len(a)] == a


# ------------------------------------------------------------ norms/gelu
def test_rms_norm_unit_scale():
    x = jnp.asarray([[3.0, -4.0]], jnp.float32)
    out = np.asarray(model.rms_norm(x, jnp.ones(2), 0.0))
    rms = np.sqrt((9 + 16) / 2)
    np.testing.assert_allclose(out, np.asarray(x) / rms, rtol=1e-6)


def test_rms_norm_gamma_scales_linearly():
    x = jnp.asarray([[1.0, 2.0, 3.0]], jnp.float32)
    g = jnp.asarray([2.0, 2.0, 2.0])
    out1 = np.asarray(model.rms_norm(x, jnp.ones(3), 1e-6))
    out2 = np.asarray(model.rms_norm(x, g, 1e-6))
    np.testing.assert_allclose(out2, 2 * out1, rtol=1e-6)


def test_gelu_fixed_points():
    out = np.asarray(model.gelu(jnp.asarray([0.0, 10.0, -10.0])))
    np.testing.assert_allclose(out[0], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[1], 10.0, rtol=1e-4)
    np.testing.assert_allclose(out[2], 0.0, atol=1e-3)
