# pytest: AOT artifacts — manifest/weights round-trip, HLO text sanity,
# golden reproducibility. Runs against artifacts/ if present, else
# regenerates into a tmpdir.
import json
import pathlib
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.model import TINY

REPO = pathlib.Path(__file__).resolve().parents[2]
ARTIFACTS = REPO / "artifacts"


@pytest.fixture(scope="module")
def artifacts_dir(tmp_path_factory):
    """Use the checked-out artifacts if they exist, otherwise build."""
    if (ARTIFACTS / "manifest.json").exists():
        return ARTIFACTS
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out / "model.hlo.txt")],
        check=True, cwd=REPO / "python",
    )
    return out


def test_manifest_schema(artifacts_dir):
    man = json.loads((artifacts_dir / "manifest.json").read_text())
    assert man["entry"] == "decode_step"
    assert man["arg_order"][-4:] == ["k_caches", "v_caches", "token_id", "pos"]
    assert man["outputs"] == ["logits", "new_k_caches", "new_v_caches"]
    names = [p["name"] for p in man["params"]]
    assert names == model.param_names(TINY)


def test_weights_bin_matches_manifest_offsets(artifacts_dir):
    man = json.loads((artifacts_dir / "manifest.json").read_text())
    blob = np.frombuffer((artifacts_dir / "weights.bin").read_bytes(),
                         dtype="<f4")
    assert blob.size == man["total_floats"]
    # offsets are contiguous and sorted
    end = 0
    for p in man["params"]:
        assert p["offset"] == end
        assert p["numel"] == int(np.prod(p["shape"])) if p["shape"] else 1
        end = p["offset"] + p["numel"]
    assert end == blob.size


def test_weights_ternary_matrices_in_domain(artifacts_dir):
    man = json.loads((artifacts_dir / "manifest.json").read_text())
    blob = np.frombuffer((artifacts_dir / "weights.bin").read_bytes(),
                         dtype="<f4")
    for p in man["params"]:
        base = p["name"].split(".")[-1]
        if base in ("wq", "wk", "wv", "wx", "w_in", "w_out", "w_head"):
            vals = blob[p["offset"]: p["offset"] + p["numel"]]
            assert set(np.unique(vals).tolist()) <= {-1.0, 0.0, 1.0}, p["name"]


def test_hlo_text_parses_shape(artifacts_dir):
    hlo = (artifacts_dir / "decode_step.hlo.txt").read_text()
    assert "ENTRY" in hlo
    assert "HloModule" in hlo
    # return_tuple=True => root is a tuple of 3
    assert hlo.count("f32[") > 10


def test_model_hlo_alias_identical(artifacts_dir):
    a = (artifacts_dir / "decode_step.hlo.txt").read_text()
    b = (artifacts_dir / "model.hlo.txt").read_text()
    assert a == b


def test_golden_consistent_with_model(artifacts_dir):
    """Re-run the jax graph from the dumped weights; the golden tokens
    must reproduce (this is exactly what the Rust runtime must match)."""
    man = json.loads((artifacts_dir / "manifest.json").read_text())
    golden = json.loads((artifacts_dir / "golden.json").read_text())
    blob = np.frombuffer((artifacts_dir / "weights.bin").read_bytes(),
                         dtype="<f4")
    params = {}
    for p in man["params"]:
        arr = blob[p["offset"]: p["offset"] + p["numel"]].reshape(p["shape"])
        params[p["name"]] = jnp.asarray(arr, jnp.float32)
    tokens = model.generate(TINY, params, golden["prompt"], golden["n_new"])
    assert tokens == golden["tokens"]


def test_golden_first_logits(artifacts_dir):
    man = json.loads((artifacts_dir / "manifest.json").read_text())
    golden = json.loads((artifacts_dir / "golden.json").read_text())
    blob = np.frombuffer((artifacts_dir / "weights.bin").read_bytes(),
                         dtype="<f4")
    params = {}
    for p in man["params"]:
        arr = blob[p["offset"]: p["offset"] + p["numel"]].reshape(p["shape"])
        params[p["name"]] = jnp.asarray(arr, jnp.float32)
    flat = model.flatten_params(TINY, params)
    k, v = model.empty_caches(TINY)
    logits, _, _ = model.decode_step(
        TINY, flat, k, v, jnp.int32(golden["prompt"][0]), jnp.int32(0)
    )
    got = np.asarray(logits)
    np.testing.assert_allclose(
        got[:8], np.asarray(golden["first_logits_prefix"]), rtol=1e-5
    )
    np.testing.assert_allclose(
        float(np.linalg.norm(got)), golden["first_logits_l2"], rtol=1e-5
    )
