# pytest: Pallas kernels vs pure-jnp ref — the CORE correctness signal.
#
# hypothesis sweeps shapes (ragged, tiny, block-boundary) and value
# distributions; every case must match the oracle EXACTLY (the integer
# path on f32 carriers is exact, see ref.py).
import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.bitlinear import bitlinear, bitlinear_matmul
from compile.kernels.qmatmul import qmatmul, qmatmul_int

# interpret-mode pallas is slow; keep hypothesis examples bounded.
SETTINGS = hypothesis.settings(
    max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)

dims = st.integers(min_value=1, max_value=96)


def _rand(rng, m, n):
    return jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))


# ---------------------------------------------------------------- bitlinear
@SETTINGS
@hypothesis.given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_bitlinear_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x_q = jnp.asarray(rng.integers(-128, 128, size=(m, k)).astype(np.float32))
    w_q = jnp.asarray(rng.integers(-1, 2, size=(k, n)).astype(np.float32))
    got = bitlinear_matmul(x_q, w_q)
    want = ref.int_matmul_ref(x_q, w_q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@SETTINGS
@hypothesis.given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_bitlinear_full_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    w_q, w_s = ref.weight_quant_ternary(_rand(rng, k, n))
    got = bitlinear(x, w_q, w_s)
    want = ref.bitlinear_ref(x, w_q, w_s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("shape", [(1, 256, 256), (1, 256, 1024),
                                   (2, 128, 128), (1, 1, 1), (128, 128, 128)])
def test_bitlinear_block_boundary_shapes(shape):
    """Exactly-at-block and single-element shapes."""
    m, k, n = shape
    rng = np.random.default_rng(1)
    x = _rand(rng, m, k)
    w_q, w_s = ref.weight_quant_ternary(_rand(rng, k, n))
    np.testing.assert_array_equal(
        np.asarray(bitlinear(x, w_q, w_s)),
        np.asarray(ref.bitlinear_ref(x, w_q, w_s)),
    )


def test_bitlinear_custom_blocks_match():
    """Block size must not change the result."""
    rng = np.random.default_rng(2)
    x = _rand(rng, 4, 200)
    w_q, w_s = ref.weight_quant_ternary(_rand(rng, 200, 72))
    base = np.asarray(bitlinear(x, w_q, w_s))
    for bm, bk, bn in [(2, 64, 32), (4, 200, 72), (1, 16, 8)]:
        got = np.asarray(bitlinear(x, w_q, w_s, bm=bm, bk=bk, bn=bn))
        np.testing.assert_array_equal(got, base)


def test_bitlinear_zero_input():
    x = jnp.zeros((3, 64), jnp.float32)
    w_q, w_s = ref.weight_quant_ternary(jnp.ones((64, 8), jnp.float32))
    out = np.asarray(bitlinear(x, w_q, w_s))
    np.testing.assert_array_equal(out, np.zeros((3, 8), np.float32))


# ----------------------------------------------------------------- qmatmul
@SETTINGS
@hypothesis.given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_qmatmul_int_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-128, 128, size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.integers(-128, 128, size=(k, n)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(qmatmul_int(a, b)), np.asarray(ref.int_matmul_ref(a, b))
    )


@SETTINGS
@hypothesis.given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_qmatmul_full_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_array_equal(
        np.asarray(qmatmul(a, b)), np.asarray(ref.qmatmul_ref(a, b))
    )


def test_qmatmul_attention_shapes():
    """The exact attention-head shapes from paper Table I (scaled down):
    (1, dh) @ (dh, l) then (1, l) @ (l, dh)."""
    rng = np.random.default_rng(3)
    dh, l = 64, 128
    q = _rand(rng, 1, dh)
    kT = _rand(rng, dh, l)
    s = _rand(rng, 1, l)
    v = _rand(rng, l, dh)
    np.testing.assert_array_equal(
        np.asarray(qmatmul(q, kT)), np.asarray(ref.qmatmul_ref(q, kT)))
    np.testing.assert_array_equal(
        np.asarray(qmatmul(s, v)), np.asarray(ref.qmatmul_ref(s, v)))


def test_qmatmul_quantization_error_bounded():
    """W8A8 result must stay within the analytic absmax error bound."""
    rng = np.random.default_rng(4)
    a, b = _rand(rng, 8, 64), _rand(rng, 64, 8)
    got = np.asarray(qmatmul(a, b))
    exact = np.asarray(a) @ np.asarray(b)
    # per-element quant error <= 0.5/scale on each operand
    a_step = np.abs(a).max() / 127.0
    b_step = np.abs(b).max() / 127.0
    bound = 64 * (
        a_step / 2 * np.abs(b).max() + b_step / 2 * np.abs(a).max()
        + a_step * b_step / 4
    )
    assert np.max(np.abs(got - exact)) <= bound


# ------------------------------------------------------------ quantization
@SETTINGS
@hypothesis.given(m=dims, n=dims, seed=st.integers(0, 2**16))
def test_weight_quant_ternary_domain(m, n, seed):
    rng = np.random.default_rng(seed)
    w_q, s = ref.weight_quant_ternary(_rand(rng, m, n))
    vals = np.unique(np.asarray(w_q))
    assert set(vals.tolist()) <= {-1.0, 0.0, 1.0}
    assert float(s) > 0


@SETTINGS
@hypothesis.given(m=dims, n=dims, seed=st.integers(0, 2**16))
def test_act_quant_int8_domain_and_roundtrip(m, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, n)
    x_q, s = ref.act_quant_int8(x)
    xq = np.asarray(x_q)
    assert xq.min() >= -128 and xq.max() <= 127
    assert np.array_equal(xq, np.round(xq))  # integral
    # round-trip error bounded by half a quantization step
    np.testing.assert_allclose(
        xq / float(s), np.asarray(x), atol=0.5 / float(s) + 1e-6
    )


def test_act_quant_saturates_exactly_at_absmax():
    x = jnp.asarray([[-2.0, 2.0, 1.0]], jnp.float32)
    x_q, s = ref.act_quant_int8(x)
    assert float(jnp.max(jnp.abs(x_q))) == 127.0


def test_act_quant_zero_input_stable():
    x_q, s = ref.act_quant_int8(jnp.zeros((4, 4), jnp.float32))
    assert np.all(np.asarray(x_q) == 0)
    assert np.isfinite(float(s))
